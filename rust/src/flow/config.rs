//! Flow configuration and stable stage fingerprinting.
//!
//! A [`FlowConfig`] carries every knob the compilation flow consumes —
//! fixed-point format, target override, basis optimization, scheduling
//! policy, timing library, power model, and stimulus parameters. Each
//! stage of a [`super::Flow`] caches its artifact keyed on a *fingerprint*
//! that mixes the stage's own config inputs with the upstream stage's
//! fingerprint, so editing the config invalidates exactly the stages
//! downstream of the change and nothing upstream of it.
//!
//! ## Stability guarantee
//!
//! Fingerprints are persisted by the on-disk artifact store
//! ([`super::store`]), so the key function must be *stable*: the same
//! inputs must hash to the same 64-bit value on every process, platform,
//! and Rust release. `std::hash::DefaultHasher` guarantees none of that
//! (its algorithm is explicitly unspecified and has changed between
//! releases), which would silently poison or invalidate a persisted
//! cache. [`StableHasher`] is therefore a hand-rolled FNV-1a 64 over a
//! canonical byte encoding:
//!
//! * integers are encoded little-endian at fixed width;
//! * strings are length-prefixed (so `("ab","c")` ≠ `("a","bc")`);
//! * `f64`s are encoded by IEEE-754 bit pattern after canonicalizing
//!   `-0.0` to `0.0` and all NaNs to one bit pattern, so numerically
//!   equal configs share a fingerprint.
//!
//! Changing any of these rules is a cache-format change and must bump
//! [`super::store::STORE_FORMAT_VERSION`].

use crate::fixedpoint::{QFormat, Q16_15};
use crate::power::{PowerModel, ICE40};
use crate::rtl::Policy;
use crate::synth::LaneWidth;
use crate::timing::{DelayModel, ICE40_LP};

/// Configuration for one compilation session.
///
/// Every field has a sensible paper default ([`FlowConfig::default`]);
/// construct with struct-update syntax to override a subset:
///
/// ```
/// use dimsynth::flow::FlowConfig;
/// use dimsynth::fixedpoint::QFormat;
///
/// let cfg = FlowConfig { qformat: QFormat::new(12, 11), ..FlowConfig::default() };
/// assert!(cfg.optimize_basis);
/// ```
#[derive(Clone, Debug)]
pub struct FlowConfig {
    /// Fixed-point format of all datapaths (default Q16.15).
    pub qformat: QFormat,
    /// Target-symbol override. `None` uses the corpus entry's target (or
    /// the target given to [`super::Flow::from_source`]).
    pub target: Option<String>,
    /// Run the cost-directed basis optimization after the raw Π search
    /// (default true; disable for ablations against the raw basis).
    pub optimize_basis: bool,
    /// Scheduling policy used for latency queries.
    pub policy: Policy,
    /// Timing library for STA.
    pub delay: DelayModel,
    /// Power model for power queries.
    pub power: PowerModel,
    /// Stimulus activations per power measurement.
    pub power_samples: u32,
    /// LFSR seed of the power-measurement stimulus stream.
    pub power_seed: u32,
    /// SIMD lane width of word-parallel simulation passes (64, 256, or
    /// 512 stimulus streams per pass; defaults to 256). Enters the
    /// power-stage fingerprint:
    /// per-lane artifacts (activity spreads, batched power estimates)
    /// are width-shaped, so artifacts produced under one width must not
    /// serve a session configured for the other.
    pub lane_width: LaneWidth,
}

impl Default for FlowConfig {
    fn default() -> Self {
        FlowConfig {
            qformat: Q16_15,
            target: None,
            optimize_basis: true,
            policy: Policy::ParallelPerPi,
            delay: ICE40_LP,
            power: ICE40,
            power_samples: 4,
            power_seed: 0xACE1,
            lane_width: LaneWidth::W256,
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit hasher over a canonical byte encoding.
///
/// Unlike `std::hash::DefaultHasher`, the output is fully specified and
/// stable across processes, platforms, and Rust releases — the property
/// the persistent artifact store ([`super::store`]) depends on. Methods
/// consume and return the hasher so fingerprints chain fluently:
///
/// ```
/// use dimsynth::flow::config::StableHasher;
///
/// let a = StableHasher::new().str("corpus").str("pendulum").finish();
/// let b = StableHasher::new().str("corpus").str("pendulum").finish();
/// assert_eq!(a, b);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct StableHasher {
    state: u64,
}

impl StableHasher {
    pub fn new() -> StableHasher {
        StableHasher { state: FNV_OFFSET }
    }

    /// Fold raw bytes into the state (FNV-1a: XOR then multiply).
    pub fn bytes(mut self, bytes: &[u8]) -> StableHasher {
        for &b in bytes {
            self.state = (self.state ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn u8(self, v: u8) -> StableHasher {
        self.bytes(&[v])
    }

    pub fn bool(self, v: bool) -> StableHasher {
        self.u8(v as u8)
    }

    pub fn u32(self, v: u32) -> StableHasher {
        self.bytes(&v.to_le_bytes())
    }

    pub fn u64(self, v: u64) -> StableHasher {
        self.bytes(&v.to_le_bytes())
    }

    /// IEEE-754 bits after [`canonical_f64_bits`] normalization.
    pub fn f64(self, v: f64) -> StableHasher {
        self.u64(canonical_f64_bits(v))
    }

    /// Length-prefixed UTF-8 bytes, so adjacent strings cannot alias.
    pub fn str(self, s: &str) -> StableHasher {
        self.u64(s.len() as u64).bytes(s.as_bytes())
    }

    pub fn finish(self) -> u64 {
        self.state
    }
}

impl Default for StableHasher {
    fn default() -> StableHasher {
        StableHasher::new()
    }
}

/// The canonical bit pattern of an `f64` for hashing: `-0.0` maps to
/// `0.0` (they compare equal, so numerically identical configs — e.g.
/// `vdd: -0.0` vs `0.0` — must share a fingerprint) and every NaN maps
/// to one pattern.
pub fn canonical_f64_bits(v: f64) -> u64 {
    if v == 0.0 {
        0.0f64.to_bits()
    } else if v.is_nan() {
        f64::NAN.to_bits()
    } else {
        v.to_bits()
    }
}

/// Mix an upstream fingerprint with a stage tag and the stage's own
/// config fingerprint.
pub(crate) fn mix(stage_tag: u64, upstream: u64, own: u64) -> u64 {
    StableHasher::new().u64(stage_tag).u64(upstream).u64(own).finish()
}

/// Hash a slice of `f64` model constants (canonical bits, length
/// prefixed).
pub(crate) fn fingerprint_f64s(values: &[f64]) -> u64 {
    let mut h = StableHasher::new().u64(values.len() as u64);
    for &v in values {
        h = h.f64(v);
    }
    h.finish()
}

impl FlowConfig {
    /// Fingerprint of the inputs the Π-search stage consumes.
    pub(crate) fn pis_inputs_fp(&self, effective_target: &str) -> u64 {
        StableHasher::new().str(effective_target).bool(self.optimize_basis).finish()
    }

    /// Fingerprint of the inputs the RTL stage consumes.
    pub(crate) fn rtl_inputs_fp(&self) -> u64 {
        StableHasher::new().u32(self.qformat.int_bits).u32(self.qformat.frac_bits).finish()
    }

    /// Fingerprint of the inputs the timing stage consumes.
    pub(crate) fn timing_inputs_fp(&self) -> u64 {
        fingerprint_f64s(&[
            self.delay.t_lut_ns,
            self.delay.t_route_ns,
            self.delay.t_reg_ns,
            self.delay.congestion,
        ])
    }

    /// Fingerprint of the inputs the power stage consumes. Lane width is
    /// included because the cached artifact's `PowerReport::spread` is
    /// measured across `lane_width.lanes()` stimulus streams — a 64-lane
    /// artifact must not serve a 256-lane config (the scalar `activity`
    /// half is lane-0-identical either way). Widening the fingerprint
    /// domain is a cache-format change, covered by the PR-4 bump of
    /// [`super::store::STORE_FORMAT_VERSION`].
    pub(crate) fn power_inputs_fp(&self) -> u64 {
        let model = fingerprint_f64s(&[self.power.vdd, self.power.c_eff, self.power.p_static]);
        StableHasher::new()
            .u32(self.power_samples)
            .u32(self.power_seed)
            .u32(self.lane_width.lanes() as u32)
            .u64(model)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_fnv1a_vectors() {
        // Published FNV-1a 64 test vectors: the empty string hashes to
        // the offset basis; "a" and "foobar" to the classic values. This
        // pins the algorithm — if it ever drifts, persisted caches break.
        assert_eq!(StableHasher::new().finish(), 0xcbf2_9ce4_8422_2325);
        assert_eq!(StableHasher::new().bytes(b"a").finish(), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(StableHasher::new().bytes(b"foobar").finish(), 0x85944171f73967e8);
    }

    #[test]
    fn length_prefix_prevents_string_aliasing() {
        let ab_c = StableHasher::new().str("ab").str("c").finish();
        let a_bc = StableHasher::new().str("a").str("bc").finish();
        assert_ne!(ab_c, a_bc);
    }

    #[test]
    fn negative_zero_and_nan_canonicalize() {
        assert_eq!(canonical_f64_bits(-0.0), canonical_f64_bits(0.0));
        assert_eq!(canonical_f64_bits(f64::NAN), canonical_f64_bits(-f64::NAN));
        assert_ne!(canonical_f64_bits(1.0), canonical_f64_bits(-1.0));
        assert_eq!(fingerprint_f64s(&[-0.0, 1.5]), fingerprint_f64s(&[0.0, 1.5]));
    }

    #[test]
    fn negative_zero_vdd_shares_power_fingerprint() {
        // `vdd: -0.0` vs `0.0` used to spuriously invalidate the power
        // stage (bit-pattern hashing without canonicalization).
        let a = FlowConfig {
            power: PowerModel { vdd: 0.0, ..ICE40 },
            ..FlowConfig::default()
        };
        let b = FlowConfig {
            power: PowerModel { vdd: -0.0, ..ICE40 },
            ..FlowConfig::default()
        };
        assert_eq!(a.power_inputs_fp(), b.power_inputs_fp());
    }

    #[test]
    fn stage_input_fingerprints_react_to_their_inputs_only() {
        let base = FlowConfig::default();
        let q = FlowConfig { qformat: QFormat::new(12, 11), ..FlowConfig::default() };
        assert_ne!(base.rtl_inputs_fp(), q.rtl_inputs_fp());
        assert_eq!(base.timing_inputs_fp(), q.timing_inputs_fp());
        assert_eq!(base.power_inputs_fp(), q.power_inputs_fp());

        let p = FlowConfig { power_seed: 0xBEEF, ..FlowConfig::default() };
        assert_ne!(base.power_inputs_fp(), p.power_inputs_fp());
        assert_eq!(base.rtl_inputs_fp(), p.rtl_inputs_fp());

        // Lane width shapes per-lane power artifacts: it must invalidate
        // the power stage and nothing upstream.
        let w = FlowConfig { lane_width: LaneWidth::W64, ..FlowConfig::default() };
        assert_ne!(base.power_inputs_fp(), w.power_inputs_fp());
        assert_eq!(base.rtl_inputs_fp(), w.rtl_inputs_fp());
        assert_eq!(base.timing_inputs_fp(), w.timing_inputs_fp());
    }

    #[test]
    fn mix_separates_stages_and_chains_upstream() {
        assert_ne!(mix(1, 7, 9), mix(2, 7, 9));
        assert_ne!(mix(1, 7, 9), mix(1, 8, 9));
        assert_ne!(mix(1, 7, 9), mix(1, 7, 10));
        assert_eq!(mix(3, 5, 11), mix(3, 5, 11));
    }
}
