//! Flow configuration and stage fingerprinting.
//!
//! A [`FlowConfig`] carries every knob the compilation flow consumes —
//! fixed-point format, target override, basis optimization, scheduling
//! policy, timing library, power model, and stimulus parameters. Each
//! stage of a [`super::Flow`] caches its artifact keyed on a *fingerprint*
//! that mixes the stage's own config inputs with the upstream stage's
//! fingerprint, so editing the config invalidates exactly the stages
//! downstream of the change and nothing upstream of it.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use crate::fixedpoint::{QFormat, Q16_15};
use crate::power::{PowerModel, ICE40};
use crate::rtl::Policy;
use crate::timing::{DelayModel, ICE40_LP};

/// Configuration for one compilation session.
///
/// Every field has a sensible paper default ([`FlowConfig::default`]);
/// construct with struct-update syntax to override a subset:
///
/// ```
/// use dimsynth::flow::FlowConfig;
/// use dimsynth::fixedpoint::QFormat;
///
/// let cfg = FlowConfig { qformat: QFormat::new(12, 11), ..FlowConfig::default() };
/// assert!(cfg.optimize_basis);
/// ```
#[derive(Clone, Debug)]
pub struct FlowConfig {
    /// Fixed-point format of all datapaths (default Q16.15).
    pub qformat: QFormat,
    /// Target-symbol override. `None` uses the corpus entry's target (or
    /// the target given to [`super::Flow::from_source`]).
    pub target: Option<String>,
    /// Run the cost-directed basis optimization after the raw Π search
    /// (default true; disable for ablations against the raw basis).
    pub optimize_basis: bool,
    /// Scheduling policy used for latency queries.
    pub policy: Policy,
    /// Timing library for STA.
    pub delay: DelayModel,
    /// Power model for power queries.
    pub power: PowerModel,
    /// Stimulus activations per power measurement.
    pub power_samples: u32,
    /// LFSR seed of the power-measurement stimulus stream.
    pub power_seed: u32,
}

impl Default for FlowConfig {
    fn default() -> Self {
        FlowConfig {
            qformat: Q16_15,
            target: None,
            optimize_basis: true,
            policy: Policy::ParallelPerPi,
            delay: ICE40_LP,
            power: ICE40,
            power_samples: 4,
            power_seed: 0xACE1,
        }
    }
}

/// Hash one value into a 64-bit fingerprint.
pub(crate) fn fingerprint<T: Hash>(value: &T) -> u64 {
    let mut h = DefaultHasher::new();
    value.hash(&mut h);
    h.finish()
}

/// Mix an upstream fingerprint with a stage tag and the stage's own
/// config fingerprint.
pub(crate) fn mix(stage_tag: u64, upstream: u64, own: u64) -> u64 {
    let mut h = DefaultHasher::new();
    stage_tag.hash(&mut h);
    upstream.hash(&mut h);
    own.hash(&mut h);
    h.finish()
}

/// Hash a slice of `f64` model constants bit-exactly.
pub(crate) fn fingerprint_f64s(values: &[f64]) -> u64 {
    let bits: Vec<u64> = values.iter().map(|v| v.to_bits()).collect();
    fingerprint(&bits)
}

impl FlowConfig {
    /// Fingerprint of the inputs the Π-search stage consumes.
    pub(crate) fn pis_inputs_fp(&self, effective_target: &str) -> u64 {
        fingerprint(&(effective_target, self.optimize_basis))
    }

    /// Fingerprint of the inputs the RTL stage consumes.
    pub(crate) fn rtl_inputs_fp(&self) -> u64 {
        fingerprint(&self.qformat)
    }

    /// Fingerprint of the inputs the timing stage consumes.
    pub(crate) fn timing_inputs_fp(&self) -> u64 {
        fingerprint_f64s(&[
            self.delay.t_lut_ns,
            self.delay.t_route_ns,
            self.delay.t_reg_ns,
            self.delay.congestion,
        ])
    }

    /// Fingerprint of the inputs the power stage consumes.
    pub(crate) fn power_inputs_fp(&self) -> u64 {
        let model = fingerprint_f64s(&[self.power.vdd, self.power.c_eff, self.power.p_static]);
        fingerprint(&(self.power_samples, self.power_seed, model))
    }
}
