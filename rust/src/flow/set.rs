//! [`FlowSet`]: a corpus-wide driver running many independent [`Flow`]
//! sessions across all cores.
//!
//! Each `Flow` owns its netlist and stage caches, so systems never share
//! mutable state and the fan-out needs no locks: the set hands disjoint
//! `&mut Flow` slices to scoped worker threads via
//! [`super::worker::parallel_map_chunks_mut`]. Results come back in
//! corpus order, so parallel and sequential runs are interchangeable.

use std::sync::Arc;

use super::session::{Flow, StageCounts};
use super::store::ArtifactStore;
use super::worker;
use super::FlowConfig;
use crate::newton;

/// A set of independent compilation sessions (typically the 7-system
/// Table-1 corpus).
pub struct FlowSet {
    flows: Vec<Flow>,
}

impl FlowSet {
    /// One session per corpus system, all sharing one config.
    pub fn corpus(config: FlowConfig) -> FlowSet {
        let flows = newton::corpus()
            .into_iter()
            .map(|e| Flow::for_entry(e, config.clone()))
            .collect();
        FlowSet { flows }
    }

    /// A set over explicit sessions.
    pub fn from_flows(flows: Vec<Flow>) -> FlowSet {
        FlowSet { flows }
    }

    /// One session per named corpus system, all sharing one config —
    /// the shape a multi-system serving deployment asks for (a subset
    /// of the corpus, order preserved). Unknown ids error up front.
    pub fn for_systems(ids: &[&str], config: FlowConfig) -> anyhow::Result<FlowSet> {
        let flows = ids
            .iter()
            .map(|id| Flow::for_system(id, config.clone()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(FlowSet { flows })
    }

    /// Attach one shared persistent [`ArtifactStore`] to every session.
    /// The store is concurrent-writer safe (temp file + atomic rename),
    /// so [`FlowSet::run_parallel`] workers — and entirely separate
    /// processes — can populate one root simultaneously.
    pub fn with_store(mut self, store: Arc<ArtifactStore>) -> FlowSet {
        for flow in &mut self.flows {
            flow.set_store(Arc::clone(&store));
        }
        self
    }

    /// Sum of the per-stage cache telemetry across all sessions.
    pub fn total_counts(&self) -> StageCounts {
        self.flows.iter().fold(StageCounts::default(), |acc, f| acc + f.counts())
    }

    pub fn len(&self) -> usize {
        self.flows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.flows.is_empty()
    }

    /// The sessions, for direct iteration.
    pub fn flows_mut(&mut self) -> &mut [Flow] {
        &mut self.flows
    }

    /// Consume the set, returning its sessions.
    pub fn into_flows(self) -> Vec<Flow> {
        self.flows
    }

    /// Run `f` over every session on the calling thread, in order.
    pub fn run_sequential<R>(&mut self, mut f: impl FnMut(&mut Flow) -> R) -> Vec<R> {
        self.flows.iter_mut().map(&mut f).collect()
    }

    /// Run `f` over every session across all cores (one scoped worker
    /// thread per core, whole sessions per worker). Output order matches
    /// session order, identical to [`FlowSet::run_sequential`].
    pub fn run_parallel<R: Send>(&mut self, f: impl Fn(&mut Flow) -> R + Sync) -> Vec<R> {
        worker::parallel_map_chunks_mut(&mut self.flows, 1, |_, flows| {
            flows.iter_mut().map(&f).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_set_has_seven_sessions() {
        let set = FlowSet::corpus(FlowConfig::default());
        assert_eq!(set.len(), 7);
        assert!(!set.is_empty());
    }

    #[test]
    fn for_systems_preserves_order_and_rejects_unknown_ids() {
        let mut set =
            FlowSet::for_systems(&["spring_mass", "pendulum"], FlowConfig::default()).unwrap();
        let ids: Vec<String> = set.run_sequential(|f| f.id().to_string());
        assert_eq!(ids, ["spring_mass", "pendulum"]);
        assert!(FlowSet::for_systems(&["warp_core"], FlowConfig::default())
            .unwrap_err()
            .to_string()
            .contains("warp_core"));
    }

    #[test]
    fn parallel_ids_match_sequential_order() {
        let mut a = FlowSet::corpus(FlowConfig::default());
        let mut b = FlowSet::corpus(FlowConfig::default());
        let seq: Vec<String> = a.run_sequential(|f| f.id().to_string());
        let par: Vec<String> = b.run_parallel(|f| f.id().to_string());
        assert_eq!(seq, par);
    }
}
