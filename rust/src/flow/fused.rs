//! The cross-system `fused` stage: cache one [`FusedNetlist`] per
//! *set* of member netlists and shard count.
//!
//! Unlike the eight per-system stages, the fused artifact is derived
//! from N flows at once, so it hangs off the [`ArtifactStore`] directly
//! rather than any single [`super::Flow`]'s LRU chain. Its fingerprint
//! hashes the member netlist fingerprints **sorted** plus the shard
//! count: membership keys the entry, not boot order. Net numbering does
//! depend on fuse order, though, so [`ensure_fused`] checks the loaded
//! artifact's recorded fuse order against the requested one and
//! recomputes (then overwrites) on mismatch — a reordered deployment is
//! a clean miss, never a scrambled scatter index.

use super::config::StableHasher;
use super::session::TAG_FUSED;
use super::store::{ArtifactStore, FusedArtifact};
use crate::shard::{FusedNetlist, ShardPlan, PARTITIONER_VERSION};
use crate::synth::Netlist;

/// Store key of a fused artifact: the member netlist fingerprints
/// (sorted — order-insensitive membership) mixed with the shard count
/// and the partitioner version under the fused stage tag. The artifact
/// carries the shard plan, so a partitioner algorithm change
/// ([`PARTITIONER_VERSION`]) makes every cached plan a clean miss.
pub fn fused_fingerprint(member_fps: &[u64], shards: usize) -> u64 {
    let mut sorted = member_fps.to_vec();
    sorted.sort_unstable();
    let mut h = StableHasher::new().u64(sorted.len() as u64);
    for fp in sorted {
        h = h.u64(fp);
    }
    let own = (shards as u64) ^ (u64::from(PARTITIONER_VERSION) << 48);
    super::config::mix(TAG_FUSED, h.finish(), own)
}

/// Ensure the fused artifact for `members` — `(netlist fingerprint,
/// netlist)` pairs in fuse order — keyed under `shards`. Lookup order
/// matches the per-system stages: disk store (when attached) → compute
/// with best-effort write-back. A stored entry whose recorded fuse
/// order differs from the requested one is treated as a miss.
pub fn ensure_fused(
    store: Option<&ArtifactStore>,
    members: &[(u64, &Netlist)],
    shards: usize,
) -> FusedArtifact {
    let member_fps: Vec<u64> = members.iter().map(|(fp, _)| *fp).collect();
    let fp = fused_fingerprint(&member_fps, shards);
    if let Some(store) = store {
        if let Some(art) = store.load::<FusedArtifact>(fp) {
            if art.member_fps == member_fps && art.shards == shards {
                return art;
            }
        }
    }
    let refs: Vec<&Netlist> = members.iter().map(|(_, nl)| *nl).collect();
    let fused = FusedNetlist::fuse_refs(&refs);
    let plan = ShardPlan::partition(&fused, shards);
    let art = FusedArtifact { fused, plan, member_fps, shards };
    if let Some(store) = store {
        if let Err(e) = store.save(fp, &art) {
            eprintln!("warning: flow store write failed for stage `fused`: {e}");
        }
    }
    art
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::NetId;
    use std::path::PathBuf;

    fn counter(bits: usize) -> Netlist {
        let mut nl = Netlist::new();
        let q: Vec<NetId> = (0..bits).map(|_| nl.dff(0, false)).collect();
        let mut carry = nl.constant(true);
        let mut next = Vec::new();
        for &qb in &q {
            let s = nl.xor2(qb, carry);
            carry = nl.and2(qb, carry);
            next.push(s);
        }
        for (d, n) in q.iter().zip(&next) {
            nl.set_dff_input(*d, *n);
        }
        nl.add_output("q", q);
        nl
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("dimsynth-fused-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn fingerprint_is_order_insensitive_but_shard_sensitive() {
        let ab = fused_fingerprint(&[1, 2], 4);
        let ba = fused_fingerprint(&[2, 1], 4);
        assert_eq!(ab, ba, "membership keys the entry, not order");
        assert_ne!(ab, fused_fingerprint(&[1, 2], 2));
        assert_ne!(ab, fused_fingerprint(&[1, 2, 3], 4));
    }

    #[test]
    fn ensure_fused_roundtrips_and_rejects_reordered_loads() {
        let dir = tmpdir("roundtrip");
        let store = ArtifactStore::open(&dir).unwrap();
        let a = counter(4);
        let b = counter(7);
        let fresh = ensure_fused(Some(&store), &[(10, &a), (20, &b)], 2);
        assert_eq!(fresh.fused.member_count(), 2);
        assert_eq!(fresh.member_fps, vec![10, 20]);

        // Same order: the stored entry serves, structurally identical —
        // including the cached shard plan and its refinement report.
        let warm = ensure_fused(Some(&store), &[(10, &a), (20, &b)], 2);
        assert_eq!(warm.member_fps, fresh.member_fps);
        assert_eq!(warm.fused.netlist.len(), fresh.fused.netlist.len());
        assert_eq!(warm.fused.members, fresh.fused.members);
        assert_eq!(warm.plan.owner, fresh.plan.owner);
        assert_eq!(warm.plan.shard_gates, fresh.plan.shard_gates);
        assert_eq!(warm.plan.cut_cost(), fresh.plan.cut_cost());
        assert_eq!(warm.plan.refinement, fresh.plan.refinement);

        // Reversed order hits the same store key but must recompute:
        // member 0's range now holds the 7-bit counter.
        let rev = ensure_fused(Some(&store), &[(20, &b), (10, &a)], 2);
        assert_eq!(rev.member_fps, vec![20, 10]);
        assert_eq!(rev.fused.members[0].net_range.1 as usize, b.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ensure_fused_works_without_a_store() {
        let a = counter(3);
        let art = ensure_fused(None, &[(1, &a)], 1);
        assert_eq!(art.fused.member_count(), 1);
        assert_eq!(art.shards, 1);
        assert_eq!(art.plan.shards, 1);
        assert!(art.plan.cuts.is_empty());
    }
}
