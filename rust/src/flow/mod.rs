//! The unified compilation-session API — the front door to the paper's
//! pipeline.
//!
//! The flow Newton description → dimensional Π-search → RTL → LUT4
//! netlist → timing/power is one dependency graph; this module exposes
//! it as one object instead of hand-stitched stage calls:
//!
//! * [`Flow`] — a compilation session for one system: a [`FlowConfig`]
//!   plus a memoized artifact graph with typed stage handles
//!   ([`Flow::parsed`], [`Flow::pis`], [`Flow::rtl`], [`Flow::netlist`],
//!   [`Flow::timing`], [`Flow::power`], [`Flow::verilog`],
//!   [`Flow::analysis`]). Each stage
//!   computes on first demand and is cached keyed on the config and the
//!   upstream stage fingerprints, so a config edit recomputes only the
//!   stages downstream of the change.
//! * [`FlowSet`] — a corpus-wide driver running independent sessions
//!   across all cores with scoped threads (each `Flow` owns its netlist,
//!   so the fan-out is lock-free and deterministic).
//! * [`ArtifactStore`] — the persistent, fingerprint-keyed artifact
//!   store ([`store`]) that carries memoization across processes.
//! * [`worker`] — the scoped-thread chunk fan-out shared by `FlowSet`
//!   and the coordinator's 64-lane power-request dispatch.
//!
//! ## Caching model
//!
//! Every stage query resolves in lookup order:
//!
//! 1. **per-stage LRU** — each stage of each `Flow` keeps a small
//!    in-memory LRU of recent artifacts, so A/B sweeps (e.g. the width
//!    sweep's return trips) revisit warm entries for free;
//! 2. **disk store** — when an [`ArtifactStore`] is attached
//!    ([`Flow::set_store`], [`FlowSet::with_store`]), missing stages
//!    are deserialized from the fingerprint-keyed on-disk store, which
//!    is what makes a second process's warm start recompute nothing;
//! 3. **compute** — and write back to the store (best-effort).
//!
//! Lookups are lazy: fingerprints derive from the source text and the
//! config alone, so a warm deep-stage query (e.g. `timing()`) loads
//! exactly one artifact — upstream stages materialize only on the
//! compute path that reads them.
//!
//! [`StageCounts`] reports all three outcomes (per-stage compute
//! counts, `memory_hits`, `disk_hits`).
//!
//! Stage fingerprints are produced by [`config::StableHasher`], a fully
//! specified FNV-1a 64 over a canonical byte encoding — stable across
//! processes, platforms, and Rust releases, which is the correctness
//! foundation of the persistent store (a `DefaultHasher` key would
//! silently invalidate or poison it). The on-disk entry format is
//! versioned by [`store::STORE_FORMAT_VERSION`]; entries with a
//! mismatched version, failed checksum, or any structural corruption
//! are treated as clean misses and recomputed, never a crash.
//!
//! ```
//! use dimsynth::flow::{Flow, FlowConfig};
//! use dimsynth::fixedpoint::QFormat;
//!
//! let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
//! let n_groups = flow.pis().unwrap().n();        // Π-search runs here...
//! let cells = flow.netlist().unwrap().lut4_cells; // ...netlist on first demand...
//! let fmax = flow.timing().unwrap().fmax_mhz;
//! assert!(n_groups >= 1 && cells > 500 && fmax > 5.0);
//! assert_eq!(flow.counts().pis, 1);               // ...and every stage is memoized.
//!
//! flow.set_qformat(QFormat::new(12, 11));         // invalidates RTL and downstream
//! let smaller = flow.netlist().unwrap().lut4_cells;
//! assert!(smaller < cells);
//! assert_eq!(flow.counts().pis, 1);               // ...but not the Π-search.
//! ```

pub mod config;
pub mod fused;
pub mod session;
pub mod set;
pub mod store;
pub mod worker;

pub use config::FlowConfig;
pub use fused::{ensure_fused, fused_fingerprint};
pub use session::{Flow, PowerReport, StageCounts};
pub use set::FlowSet;
pub use store::{ArtifactStore, FusedArtifact, GcReport, StageStats, StoreStats, STORE_FORMAT_VERSION};
