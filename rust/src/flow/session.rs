//! The [`Flow`] compilation session: one system, one config, a memoized
//! graph of typed stage artifacts.

use super::config::{fingerprint, mix, FlowConfig};
use crate::newton::{self, CorpusEntry, SystemModel};
use crate::pisearch::{self, CostModel, PiAnalysis};
use crate::power::{self, ActivityReport, PowerModel};
use crate::rtl::{self, PiModuleDesign};
use crate::synth::{self, MappedDesign};
use crate::timing::{self, TimingReport};

// Stage tags keep fingerprints of different stages disjoint even when
// their config inputs coincide.
const TAG_PARSE: u64 = 0x01;
const TAG_PIS: u64 = 0x02;
const TAG_RTL: u64 = 0x03;
const TAG_NETLIST: u64 = 0x04;
const TAG_TIMING: u64 = 0x05;
const TAG_POWER: u64 = 0x06;
const TAG_VERILOG: u64 = 0x07;

/// Where a flow's Newton description comes from.
#[derive(Clone, Debug)]
enum FlowSource {
    /// A corpus system (carries the paper's target symbol and the
    /// Table-1 metadata).
    Corpus(CorpusEntry),
    /// Inline Newton source (e.g. a user-authored `.nt` file).
    Inline { name: String, source: String, target: String },
}

impl FlowSource {
    fn id(&self) -> &str {
        match self {
            FlowSource::Corpus(e) => e.id,
            FlowSource::Inline { name, .. } => name,
        }
    }

    fn default_target(&self) -> &str {
        match self {
            FlowSource::Corpus(e) => e.target,
            FlowSource::Inline { target, .. } => target,
        }
    }

    fn fingerprint(&self) -> u64 {
        match self {
            FlowSource::Corpus(e) => fingerprint(&("corpus", e.id, e.source)),
            FlowSource::Inline { name, source, .. } => {
                fingerprint(&("inline", name.as_str(), source.as_str()))
            }
        }
    }

    fn load(&self) -> anyhow::Result<SystemModel> {
        match self {
            FlowSource::Corpus(e) => newton::load_entry(e),
            FlowSource::Inline { name, source, .. } => {
                let models = newton::load(source)?;
                models
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("no invariant in `{name}`"))
            }
        }
    }
}

/// One memoized stage slot: the artifact plus the fingerprint it was
/// computed under.
#[derive(Clone, Debug)]
struct Stage<T> {
    slot: Option<(u64, T)>,
}

impl<T> Stage<T> {
    const fn new() -> Stage<T> {
        Stage { slot: None }
    }

    fn is_fresh(&self, fp: u64) -> bool {
        matches!(&self.slot, Some((cached, _)) if *cached == fp)
    }

    fn store(&mut self, fp: u64, value: T) {
        self.slot = Some((fp, value));
    }

    fn value(&self) -> &T {
        self.slot.as_ref().map(|(_, v)| v).expect("stage was just ensured")
    }
}

/// How many times each stage has actually computed (cache misses). Used
/// by tests and the memoization bench; repeated queries of an unchanged
/// config must not grow these.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct StageCounts {
    pub parsed: u32,
    pub pis: u32,
    pub rtl: u32,
    pub netlist: u32,
    pub timing: u32,
    pub power: u32,
    pub verilog: u32,
}

/// A power query answer: the measured activity plus the model it was
/// priced under and the paper's two reference operating points.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    /// Switching activity under the configured LFSR stimulus.
    pub activity: ActivityReport,
    /// Power model the milliwatt figures were computed with.
    pub model: PowerModel,
    /// Average power at 6 MHz (mW).
    pub mw_6mhz: f64,
    /// Average power at 12 MHz (mW).
    pub mw_12mhz: f64,
}

impl PowerReport {
    /// Average power (mW) at an arbitrary clock frequency.
    pub fn mw_at(&self, f_hz: f64) -> f64 {
        power::average_power_mw(&self.model, &self.activity, f_hz)
    }
}

/// A compilation session for one physical system.
///
/// `Flow` is the front door to the whole paper pipeline: Newton
/// description → dimensional Π-search → RTL → LUT4 netlist →
/// timing/power. Each stage is computed on first demand and cached keyed
/// on the config and the upstream stage fingerprints, so re-queries are
/// free and a config edit (e.g. [`Flow::set_qformat`]) recomputes only
/// the stages downstream of the change.
pub struct Flow {
    source: FlowSource,
    /// Fingerprint of the (immutable) source, computed once at
    /// construction so deep stage queries don't re-hash the Newton text.
    source_fp: u64,
    config: FlowConfig,
    parsed: Stage<SystemModel>,
    pis: Stage<PiAnalysis>,
    rtl: Stage<PiModuleDesign>,
    netlist: Stage<MappedDesign>,
    timing: Stage<TimingReport>,
    power: Stage<PowerReport>,
    verilog: Stage<String>,
    counts: StageCounts,
}

impl Flow {
    fn new(source: FlowSource, config: FlowConfig) -> Flow {
        Flow {
            source_fp: source.fingerprint(),
            source,
            config,
            parsed: Stage::new(),
            pis: Stage::new(),
            rtl: Stage::new(),
            netlist: Stage::new(),
            timing: Stage::new(),
            power: Stage::new(),
            verilog: Stage::new(),
            counts: StageCounts::default(),
        }
    }

    /// Session for one corpus entry.
    pub fn for_entry(entry: CorpusEntry, config: FlowConfig) -> Flow {
        Flow::new(FlowSource::Corpus(entry), config)
    }

    /// Session for a corpus system by id.
    pub fn for_system(id: &str, config: FlowConfig) -> anyhow::Result<Flow> {
        let entry = newton::by_id(id).ok_or_else(|| anyhow::anyhow!("unknown system `{id}`"))?;
        Ok(Flow::for_entry(entry, config))
    }

    /// Session for inline Newton source (e.g. a `.nt` file's contents).
    /// `name` labels reports; `target` is the inference target symbol.
    pub fn from_source(name: &str, source: &str, target: &str, config: FlowConfig) -> Flow {
        Flow::new(
            FlowSource::Inline {
                name: name.to_string(),
                source: source.to_string(),
                target: target.to_string(),
            },
            config,
        )
    }

    /// The system identifier this session compiles.
    pub fn id(&self) -> &str {
        self.source.id()
    }

    /// The corpus entry, when this session compiles a corpus system.
    pub fn corpus_entry(&self) -> Option<&CorpusEntry> {
        match &self.source {
            FlowSource::Corpus(e) => Some(e),
            FlowSource::Inline { .. } => None,
        }
    }

    /// The effective target symbol (config override, else the source's).
    pub fn target(&self) -> &str {
        self.config.target.as_deref().unwrap_or_else(|| self.source.default_target())
    }

    /// Current configuration.
    pub fn config(&self) -> &FlowConfig {
        &self.config
    }

    /// Replace the whole configuration. Cached stages whose inputs did
    /// not change stay valid; the rest recompute on next demand.
    pub fn set_config(&mut self, config: FlowConfig) {
        self.config = config;
    }

    /// Change the fixed-point format (invalidates RTL and downstream;
    /// parse and Π-search stay cached).
    pub fn set_qformat(&mut self, q: crate::fixedpoint::QFormat) {
        self.config.qformat = q;
    }

    /// Change the scheduling policy (latency queries only; no cached
    /// stage depends on it).
    pub fn set_policy(&mut self, policy: rtl::Policy) {
        self.config.policy = policy;
    }

    /// Change the power stimulus (invalidates only the power stage).
    pub fn set_power_stimulus(&mut self, samples: u32, seed: u32) {
        self.config.power_samples = samples;
        self.config.power_seed = seed;
    }

    /// Per-stage compute counts (cache misses so far).
    pub fn counts(&self) -> StageCounts {
        self.counts
    }

    // ---- stage graph -----------------------------------------------------
    //
    // Each `ensure_*` returns the stage's fingerprint after guaranteeing
    // the cached artifact matches it; the public accessors borrow the
    // cached value afterwards. Fingerprints chain upstream→downstream, so
    // freshness checks pull the whole prefix of the pipeline on demand.

    fn ensure_parsed(&mut self) -> anyhow::Result<u64> {
        let fp = mix(TAG_PARSE, self.source_fp, 0);
        if !self.parsed.is_fresh(fp) {
            let model = self.source.load()?;
            self.counts.parsed += 1;
            self.parsed.store(fp, model);
        }
        Ok(fp)
    }

    fn ensure_pis(&mut self) -> anyhow::Result<u64> {
        let upstream = self.ensure_parsed()?;
        let own = self.config.pis_inputs_fp(self.target());
        let fp = mix(TAG_PIS, upstream, own);
        if !self.pis.is_fresh(fp) {
            let target = self.target().to_string();
            let model = self.parsed.value();
            let mut analysis = pisearch::analyze(model, &target)?;
            if self.config.optimize_basis {
                pisearch::optimize(&mut analysis, &CostModel::default());
            }
            self.counts.pis += 1;
            self.pis.store(fp, analysis);
        }
        Ok(fp)
    }

    fn ensure_rtl(&mut self) -> anyhow::Result<u64> {
        let upstream = self.ensure_pis()?;
        let fp = mix(TAG_RTL, upstream, self.config.rtl_inputs_fp());
        if !self.rtl.is_fresh(fp) {
            let design = rtl::build(self.pis.value(), self.config.qformat);
            self.counts.rtl += 1;
            self.rtl.store(fp, design);
        }
        Ok(fp)
    }

    fn ensure_netlist(&mut self) -> anyhow::Result<u64> {
        let upstream = self.ensure_rtl()?;
        let fp = mix(TAG_NETLIST, upstream, 0);
        if !self.netlist.is_fresh(fp) {
            let mapped = synth::map_design(self.rtl.value());
            self.counts.netlist += 1;
            self.netlist.store(fp, mapped);
        }
        Ok(fp)
    }

    fn ensure_timing(&mut self) -> anyhow::Result<u64> {
        let upstream = self.ensure_netlist()?;
        let fp = mix(TAG_TIMING, upstream, self.config.timing_inputs_fp());
        if !self.timing.is_fresh(fp) {
            let report = timing::analyze(&self.netlist.value().netlist, &self.config.delay);
            self.counts.timing += 1;
            self.timing.store(fp, report);
        }
        Ok(fp)
    }

    fn ensure_power(&mut self) -> anyhow::Result<u64> {
        let upstream = self.ensure_netlist()?;
        let fp = mix(TAG_POWER, upstream, self.config.power_inputs_fp());
        if !self.power.is_fresh(fp) {
            let activity = power::measure_activity(
                &self.netlist.value().netlist,
                self.rtl.value(),
                self.config.power_samples,
                self.config.power_seed,
            );
            let model = self.config.power;
            let report = PowerReport {
                activity,
                model,
                mw_6mhz: power::average_power_mw(&model, &activity, 6.0e6),
                mw_12mhz: power::average_power_mw(&model, &activity, 12.0e6),
            };
            self.counts.power += 1;
            self.power.store(fp, report);
        }
        Ok(fp)
    }

    fn ensure_verilog(&mut self) -> anyhow::Result<u64> {
        let upstream = self.ensure_rtl()?;
        let fp = mix(TAG_VERILOG, upstream, 0);
        if !self.verilog.is_fresh(fp) {
            let text = rtl::verilog::emit(self.rtl.value());
            self.counts.verilog += 1;
            self.verilog.store(fp, text);
        }
        Ok(fp)
    }

    // ---- typed stage handles ---------------------------------------------

    /// The dimension-checked system model (frontend stage).
    pub fn parsed(&mut self) -> anyhow::Result<&SystemModel> {
        self.ensure_parsed()?;
        Ok(self.parsed.value())
    }

    /// The (optimized) Π-search result (analysis stage).
    pub fn pis(&mut self) -> anyhow::Result<&PiAnalysis> {
        self.ensure_pis()?;
        Ok(self.pis.value())
    }

    /// The generated RTL module (backend stage).
    pub fn rtl(&mut self) -> anyhow::Result<&PiModuleDesign> {
        self.ensure_rtl()?;
        Ok(self.rtl.value())
    }

    /// The LUT4-mapped netlist with resource accounting (implementation
    /// stage).
    pub fn netlist(&mut self) -> anyhow::Result<&MappedDesign> {
        self.ensure_netlist()?;
        Ok(self.netlist.value())
    }

    /// The RTL design together with its mapped netlist, from one
    /// consistent cache generation — for consumers (like gate-level
    /// simulation) that must never pair a stale design with a fresh
    /// netlist across a config change.
    pub fn rtl_and_netlist(&mut self) -> anyhow::Result<(&PiModuleDesign, &MappedDesign)> {
        self.ensure_netlist()?;
        Ok((self.rtl.value(), self.netlist.value()))
    }

    /// Static timing of the mapped netlist under the configured library.
    pub fn timing(&mut self) -> anyhow::Result<TimingReport> {
        self.ensure_timing()?;
        Ok(*self.timing.value())
    }

    /// Switching-activity power estimate under the configured stimulus.
    pub fn power(&mut self) -> anyhow::Result<PowerReport> {
        self.ensure_power()?;
        Ok(*self.power.value())
    }

    /// The emitted Verilog text.
    pub fn verilog(&mut self) -> anyhow::Result<&str> {
        self.ensure_verilog()?;
        Ok(self.verilog.value().as_str())
    }

    /// Module latency in cycles under the configured scheduling policy
    /// (derived from the RTL stage; cheap, not cached).
    pub fn latency(&mut self) -> anyhow::Result<u64> {
        let policy = self.config.policy;
        Ok(rtl::module_latency(self.rtl()?, policy))
    }
}
