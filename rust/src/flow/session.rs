//! The [`Flow`] compilation session: one system, one config, a memoized
//! graph of typed stage artifacts.
//!
//! Every stage query resolves in lookup order **per-stage LRU → disk
//! store → compute** (step 2 only when a persistent store is attached
//! via [`Flow::set_store`]); [`StageCounts`] distinguishes the three
//! outcomes. Lookups are *lazy*: stage fingerprints derive from the
//! source fingerprint and the config alone, so a warm `timing()` or
//! `power()` query loads exactly its own artifact — upstream stages
//! materialize only when a stage actually computes.

use std::sync::Arc;

use super::config::{mix, FlowConfig, StableHasher};
use super::store::{Artifact, ArtifactStore, Lru, LruHit};
use crate::analyze::AnalysisReport;
use crate::newton::{self, CorpusEntry, SystemModel};
use crate::pisearch::{self, CostModel, PiAnalysis};
use crate::power::{self, ActivityReport, ActivitySpread, PowerModel};
use crate::stim::LfsrBank;
use crate::synth::{self, LaneWidth, MappedDesign, W256, W512};
use crate::timing::{self, TimingReport};
use crate::rtl::{self, PiModuleDesign};

// Stage tags keep fingerprints of different stages disjoint even when
// their config inputs coincide.
const TAG_PARSE: u64 = 0x01;
const TAG_PIS: u64 = 0x02;
const TAG_RTL: u64 = 0x03;
const TAG_NETLIST: u64 = 0x04;
const TAG_TIMING: u64 = 0x05;
const TAG_POWER: u64 = 0x06;
const TAG_VERILOG: u64 = 0x07;
/// The cross-system fused stage ([`super::fused`]) — not part of any
/// single `Flow`'s chain, but its tag must stay disjoint from these.
pub(crate) const TAG_FUSED: u64 = 0x08;
const TAG_ANALYZE: u64 = 0x09;

/// Version of the static verifier mixed into the analyze stage
/// fingerprint: bump when a pass's findings change so stale clean
/// reports cached on disk cannot mask newly detectable defects.
const ANALYZE_VERSION: u64 = 1;

/// Depth of each per-stage in-memory LRU: deep enough that an A/B sweep
/// like the width sweep (5 formats) returns to warm entries instead of
/// recomputing.
const STAGE_LRU_DEPTH: usize = 8;

/// Where a flow's Newton description comes from.
#[derive(Clone, Debug)]
enum FlowSource {
    /// A corpus system (carries the paper's target symbol and the
    /// Table-1 metadata).
    Corpus(CorpusEntry),
    /// Inline Newton source (e.g. a user-authored `.nt` file).
    Inline { name: String, source: String, target: String },
}

impl FlowSource {
    fn id(&self) -> &str {
        match self {
            FlowSource::Corpus(e) => e.id,
            FlowSource::Inline { name, .. } => name,
        }
    }

    fn default_target(&self) -> &str {
        match self {
            FlowSource::Corpus(e) => e.target,
            FlowSource::Inline { target, .. } => target,
        }
    }

    /// Stable content fingerprint of the Newton source (hashes the text,
    /// so the same system keys the same artifacts in every process).
    fn fingerprint(&self) -> u64 {
        match self {
            FlowSource::Corpus(e) => {
                StableHasher::new().str("corpus").str(e.id).str(e.source).finish()
            }
            FlowSource::Inline { name, source, .. } => {
                StableHasher::new().str("inline").str(name).str(source).finish()
            }
        }
    }

    fn load(&self) -> anyhow::Result<SystemModel> {
        match self {
            FlowSource::Corpus(e) => newton::load_entry(e),
            FlowSource::Inline { name, source, .. } => {
                let models = newton::load(source)?;
                models
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("no invariant in `{name}`"))
            }
        }
    }
}

/// Per-stage cache telemetry: how often each stage actually computed
/// (cache misses, one counter per stage), plus how many stage queries
/// were served without computing — from a deeper entry of the in-memory
/// LRU (`memory_hits`, e.g. a sweep's return trip) or deserialized from
/// the persistent store (`disk_hits`, e.g. a warm process start).
/// Repeated queries of an unchanged config touch no counter at all.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct StageCounts {
    pub parsed: u32,
    pub pis: u32,
    pub rtl: u32,
    pub netlist: u32,
    pub timing: u32,
    pub power: u32,
    pub verilog: u32,
    pub analyze: u32,
    /// Stage queries served by promoting a non-front LRU entry.
    pub memory_hits: u32,
    /// Stage artifacts loaded from the persistent on-disk store.
    pub disk_hits: u32,
}

impl StageCounts {
    /// Total stage computations (cache misses) across all stages.
    pub fn recomputes(&self) -> u32 {
        self.parsed
            + self.pis
            + self.rtl
            + self.netlist
            + self.timing
            + self.power
            + self.verilog
            + self.analyze
    }
}

impl std::ops::Add for StageCounts {
    type Output = StageCounts;

    fn add(self, rhs: StageCounts) -> StageCounts {
        StageCounts {
            parsed: self.parsed + rhs.parsed,
            pis: self.pis + rhs.pis,
            rtl: self.rtl + rhs.rtl,
            netlist: self.netlist + rhs.netlist,
            timing: self.timing + rhs.timing,
            power: self.power + rhs.power,
            verilog: self.verilog + rhs.verilog,
            analyze: self.analyze + rhs.analyze,
            memory_hits: self.memory_hits + rhs.memory_hits,
            disk_hits: self.disk_hits + rhs.disk_hits,
        }
    }
}

/// A power query answer: the measured activity plus the model it was
/// priced under and the paper's two reference operating points.
///
/// The measurement runs word-parallel at the config's
/// [`FlowConfig::lane_width`]: lane 0 is seeded with `power_seed`, so
/// `activity` (and the mW figures derived from it) is bit-identical to
/// the scalar single-stream measurement this stage historically ran,
/// while the remaining lanes yield the width-shaped `spread` from the
/// same pass — which is why the lane width is part of this stage's
/// cache fingerprint.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    /// Switching activity under the configured LFSR stimulus (lane 0 of
    /// the batched measurement; width-independent).
    pub activity: ActivityReport,
    /// Per-lane activity statistics across the full lane width.
    pub spread: ActivitySpread,
    /// Power model the milliwatt figures were computed with.
    pub model: PowerModel,
    /// Average power at 6 MHz (mW).
    pub mw_6mhz: f64,
    /// Average power at 12 MHz (mW).
    pub mw_12mhz: f64,
}

impl PowerReport {
    /// Average power (mW) at an arbitrary clock frequency.
    pub fn mw_at(&self, f_hz: f64) -> f64 {
        power::average_power_mw(&self.model, &self.activity, f_hz)
    }
}

/// A compilation session for one physical system.
///
/// `Flow` is the front door to the whole paper pipeline: Newton
/// description → dimensional Π-search → RTL → LUT4 netlist →
/// timing/power. Each stage is computed on first demand and cached keyed
/// on the config and the upstream stage fingerprints, so re-queries are
/// free and a config edit (e.g. [`Flow::set_qformat`]) recomputes only
/// the stages downstream of the change. Each stage keeps a small LRU of
/// recent artifacts (sweep return trips are free), and an optional
/// shared [`ArtifactStore`] carries artifacts across processes.
pub struct Flow {
    source: FlowSource,
    /// Fingerprint of the (immutable) source, computed once at
    /// construction so deep stage queries don't re-hash the Newton text.
    source_fp: u64,
    config: FlowConfig,
    /// Persistent artifact store consulted between the LRU and compute.
    store: Option<Arc<ArtifactStore>>,
    parsed: Lru<SystemModel>,
    pis: Lru<PiAnalysis>,
    /// The design and netlist stages cache `Arc`-wrapped artifacts:
    /// serving consumers ([`Flow::rtl_shared`], [`Flow::netlist_shared`])
    /// hold the *same* allocation the LRU does, so a multi-endpoint
    /// deployment keeps exactly one resident copy per artifact instead
    /// of a deep clone per endpoint.
    rtl: Lru<Arc<PiModuleDesign>>,
    netlist: Lru<Arc<MappedDesign>>,
    timing: Lru<TimingReport>,
    power: Lru<PowerReport>,
    verilog: Lru<String>,
    analyze: Lru<AnalysisReport>,
    counts: StageCounts,
}

impl Flow {
    fn new(source: FlowSource, config: FlowConfig) -> Flow {
        Flow {
            source_fp: source.fingerprint(),
            source,
            config,
            store: None,
            parsed: Lru::new(STAGE_LRU_DEPTH),
            pis: Lru::new(STAGE_LRU_DEPTH),
            rtl: Lru::new(STAGE_LRU_DEPTH),
            netlist: Lru::new(STAGE_LRU_DEPTH),
            timing: Lru::new(STAGE_LRU_DEPTH),
            power: Lru::new(STAGE_LRU_DEPTH),
            verilog: Lru::new(STAGE_LRU_DEPTH),
            analyze: Lru::new(STAGE_LRU_DEPTH),
            counts: StageCounts::default(),
        }
    }

    /// Session for one corpus entry.
    pub fn for_entry(entry: CorpusEntry, config: FlowConfig) -> Flow {
        Flow::new(FlowSource::Corpus(entry), config)
    }

    /// Session for a corpus system by id.
    pub fn for_system(id: &str, config: FlowConfig) -> anyhow::Result<Flow> {
        let entry = newton::by_id(id).ok_or_else(|| anyhow::anyhow!("unknown system `{id}`"))?;
        Ok(Flow::for_entry(entry, config))
    }

    /// Session for inline Newton source (e.g. a `.nt` file's contents).
    /// `name` labels reports; `target` is the inference target symbol.
    pub fn from_source(name: &str, source: &str, target: &str, config: FlowConfig) -> Flow {
        Flow::new(
            FlowSource::Inline {
                name: name.to_string(),
                source: source.to_string(),
                target: target.to_string(),
            },
            config,
        )
    }

    /// Attach a persistent artifact store: stage lookups then go LRU →
    /// disk → compute, and computed artifacts are written back
    /// (best-effort — storage failures never fail compilation).
    pub fn set_store(&mut self, store: Arc<ArtifactStore>) {
        self.store = Some(store);
    }

    /// Builder-style [`Flow::set_store`].
    pub fn with_store(mut self, store: Arc<ArtifactStore>) -> Flow {
        self.set_store(store);
        self
    }

    /// The attached persistent store, if any.
    pub fn store(&self) -> Option<&Arc<ArtifactStore>> {
        self.store.as_ref()
    }

    /// The system identifier this session compiles.
    pub fn id(&self) -> &str {
        self.source.id()
    }

    /// The corpus entry, when this session compiles a corpus system.
    pub fn corpus_entry(&self) -> Option<&CorpusEntry> {
        match &self.source {
            FlowSource::Corpus(e) => Some(e),
            FlowSource::Inline { .. } => None,
        }
    }

    /// The effective target symbol (config override, else the source's).
    pub fn target(&self) -> &str {
        self.config.target.as_deref().unwrap_or_else(|| self.source.default_target())
    }

    /// Current configuration.
    pub fn config(&self) -> &FlowConfig {
        &self.config
    }

    /// Replace the whole configuration. Cached stages whose inputs did
    /// not change stay valid; the rest recompute on next demand.
    pub fn set_config(&mut self, config: FlowConfig) {
        self.config = config;
    }

    /// Change the fixed-point format (invalidates RTL and downstream;
    /// parse and Π-search stay cached).
    pub fn set_qformat(&mut self, q: crate::fixedpoint::QFormat) {
        self.config.qformat = q;
    }

    /// Change the scheduling policy (latency queries only; no cached
    /// stage depends on it).
    pub fn set_policy(&mut self, policy: rtl::Policy) {
        self.config.policy = policy;
    }

    /// Change the power stimulus (invalidates only the power stage).
    pub fn set_power_stimulus(&mut self, samples: u32, seed: u32) {
        self.config.power_samples = samples;
        self.config.power_seed = seed;
    }

    /// Change the SIMD lane width of word-parallel simulation passes
    /// (invalidates only the power stage — per-lane artifacts are
    /// width-shaped).
    pub fn set_lane_width(&mut self, width: crate::synth::LaneWidth) {
        self.config.lane_width = width;
    }

    /// Per-stage cache telemetry (compute counts and hit sources).
    pub fn counts(&self) -> StageCounts {
        self.counts
    }

    /// Best-effort write-back to the attached store.
    fn save_artifact<A: Artifact>(&self, fp: u64, artifact: &A) {
        if let Some(store) = &self.store {
            if let Err(e) = store.save(fp, artifact) {
                eprintln!(
                    "warning: flow store write failed for stage `{}`: {e}",
                    A::STAGE.dir_name()
                );
            }
        }
    }

    /// Disk half of the lookup order (`None` when no store is attached
    /// or the entry is absent/invalid).
    fn load_artifact<A: Artifact>(&self, fp: u64) -> Option<A> {
        self.store.as_ref()?.load(fp)
    }

    // ---- fingerprint chain -----------------------------------------------
    //
    // Stage fingerprints derive from the (precomputed) source fingerprint
    // and the config alone — no artifact is needed to decide whether a
    // cached stage is fresh. That makes warm queries *lazy*: a `timing()`
    // hit in the LRU or on disk answers without deserializing the
    // parse/Π/RTL/netlist artifacts it was derived from. Upstream stages
    // materialize only on the compute path, which actually reads them.

    fn fp_parsed(&self) -> u64 {
        mix(TAG_PARSE, self.source_fp, 0)
    }

    fn fp_pis(&self) -> u64 {
        mix(TAG_PIS, self.fp_parsed(), self.config.pis_inputs_fp(self.target()))
    }

    fn fp_rtl(&self) -> u64 {
        mix(TAG_RTL, self.fp_pis(), self.config.rtl_inputs_fp())
    }

    fn fp_netlist(&self) -> u64 {
        mix(TAG_NETLIST, self.fp_rtl(), 0)
    }

    /// The netlist stage's fingerprint — the per-member key the
    /// cross-system fused stage ([`super::fused`]) is derived from.
    /// Purely config-derived, so it never forces a compute.
    pub fn netlist_fingerprint(&self) -> u64 {
        self.fp_netlist()
    }

    /// The analyze stage's fingerprint — the store key of this
    /// session's [`AnalysisReport`]. Purely config-derived, so it never
    /// forces a compute.
    pub fn analysis_fingerprint(&self) -> u64 {
        self.fp_analyze()
    }

    fn fp_timing(&self) -> u64 {
        mix(TAG_TIMING, self.fp_netlist(), self.config.timing_inputs_fp())
    }

    fn fp_power(&self) -> u64 {
        mix(TAG_POWER, self.fp_netlist(), self.config.power_inputs_fp())
    }

    fn fp_verilog(&self) -> u64 {
        mix(TAG_VERILOG, self.fp_rtl(), 0)
    }

    fn fp_analyze(&self) -> u64 {
        // Derived from the netlist fingerprint: the verifier reads the
        // parsed model, the RTL design, and the mapped netlist, and the
        // netlist fp already transitively keys all three.
        mix(TAG_ANALYZE, self.fp_netlist(), ANALYZE_VERSION)
    }

    // ---- stage graph -----------------------------------------------------
    //
    // Each `ensure_*` returns the stage's fingerprint after guaranteeing
    // the front of the stage's LRU holds the matching artifact; the
    // public accessors borrow that front value afterwards. The lookup
    // order at every stage is LRU → disk store → compute; only the
    // compute branch ensures the upstream stages it reads.

    fn ensure_parsed(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_parsed();
        match self.parsed.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(model) = self.load_artifact::<SystemModel>(fp) {
                    self.counts.disk_hits += 1;
                    self.parsed.insert(fp, model);
                } else {
                    let model = self.source.load()?;
                    self.counts.parsed += 1;
                    self.save_artifact(fp, &model);
                    self.parsed.insert(fp, model);
                }
            }
        }
        Ok(fp)
    }

    fn ensure_pis(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_pis();
        match self.pis.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(analysis) = self.load_artifact::<PiAnalysis>(fp) {
                    self.counts.disk_hits += 1;
                    self.pis.insert(fp, analysis);
                } else {
                    self.ensure_parsed()?;
                    let target = self.target().to_string();
                    let model = self.parsed.value();
                    let mut analysis = pisearch::analyze(model, &target)?;
                    if self.config.optimize_basis {
                        pisearch::optimize(&mut analysis, &CostModel::default());
                    }
                    self.counts.pis += 1;
                    self.save_artifact(fp, &analysis);
                    self.pis.insert(fp, analysis);
                }
            }
        }
        Ok(fp)
    }

    fn ensure_rtl(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_rtl();
        match self.rtl.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(design) = self.load_artifact::<PiModuleDesign>(fp) {
                    self.counts.disk_hits += 1;
                    self.rtl.insert(fp, Arc::new(design));
                } else {
                    self.ensure_pis()?;
                    let design = rtl::build(self.pis.value(), self.config.qformat);
                    self.counts.rtl += 1;
                    self.save_artifact(fp, &design);
                    self.rtl.insert(fp, Arc::new(design));
                }
            }
        }
        Ok(fp)
    }

    fn ensure_netlist(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_netlist();
        match self.netlist.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(mapped) = self.load_artifact::<MappedDesign>(fp) {
                    self.counts.disk_hits += 1;
                    self.netlist.insert(fp, Arc::new(mapped));
                } else {
                    self.ensure_rtl()?;
                    let mapped = synth::map_design(self.rtl.value());
                    self.counts.netlist += 1;
                    self.save_artifact(fp, &mapped);
                    self.netlist.insert(fp, Arc::new(mapped));
                }
            }
        }
        Ok(fp)
    }

    fn ensure_timing(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_timing();
        match self.timing.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(report) = self.load_artifact::<TimingReport>(fp) {
                    self.counts.disk_hits += 1;
                    self.timing.insert(fp, report);
                } else {
                    self.ensure_netlist()?;
                    let report =
                        timing::analyze(&self.netlist.value().netlist, &self.config.delay);
                    self.counts.timing += 1;
                    self.save_artifact(fp, &report);
                    self.timing.insert(fp, report);
                }
            }
        }
        Ok(fp)
    }

    fn ensure_power(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_power();
        match self.power.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(report) = self.load_artifact::<PowerReport>(fp) {
                    self.counts.disk_hits += 1;
                    self.power.insert(fp, report);
                } else {
                    // Measuring reads both the design and the netlist;
                    // materialize them only on this compute path.
                    self.ensure_rtl()?;
                    self.ensure_netlist()?;
                    // One word-parallel pass at the configured lane
                    // width. Lane 0 carries `power_seed` itself —
                    // bit-identical to the scalar single-stream
                    // measurement — and the derived tail seeds turn the
                    // same pass into the width-shaped spread.
                    let netlist = &self.netlist.value().netlist;
                    let design = self.rtl.value();
                    let samples = self.config.power_samples;
                    let seed = self.config.power_seed;
                    let batch = match self.config.lane_width {
                        LaneWidth::W64 => {
                            let mut seeds = LfsrBank::<u64>::lane_seeds(seed);
                            seeds[0] = seed;
                            power::measure_activity_batch_wide::<u64>(
                                netlist, design, samples, &seeds, None,
                            )
                        }
                        LaneWidth::W256 => {
                            let mut seeds = LfsrBank::<W256>::lane_seeds(seed);
                            seeds[0] = seed;
                            power::measure_activity_batch_wide::<W256>(
                                netlist, design, samples, &seeds, None,
                            )
                        }
                        LaneWidth::W512 => {
                            let mut seeds = LfsrBank::<W512>::lane_seeds(seed);
                            seeds[0] = seed;
                            power::measure_activity_batch_wide::<W512>(
                                netlist, design, samples, &seeds, None,
                            )
                        }
                    };
                    let activity = batch.lane(0);
                    let spread = ActivitySpread::of(&batch);
                    let model = self.config.power;
                    let report = PowerReport {
                        activity,
                        spread,
                        model,
                        mw_6mhz: power::average_power_mw(&model, &activity, 6.0e6),
                        mw_12mhz: power::average_power_mw(&model, &activity, 12.0e6),
                    };
                    self.counts.power += 1;
                    self.save_artifact(fp, &report);
                    self.power.insert(fp, report);
                }
            }
        }
        Ok(fp)
    }

    fn ensure_verilog(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_verilog();
        match self.verilog.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(text) = self.load_artifact::<String>(fp) {
                    self.counts.disk_hits += 1;
                    self.verilog.insert(fp, text);
                } else {
                    self.ensure_rtl()?;
                    let text = rtl::verilog::emit(self.rtl.value());
                    self.counts.verilog += 1;
                    self.save_artifact(fp, &text);
                    self.verilog.insert(fp, text);
                }
            }
        }
        Ok(fp)
    }

    fn ensure_analyze(&mut self) -> anyhow::Result<u64> {
        let fp = self.fp_analyze();
        match self.analyze.promote(fp) {
            LruHit::Fresh => {}
            LruHit::Promoted => self.counts.memory_hits += 1,
            LruHit::Miss => {
                if let Some(report) = self.load_artifact::<AnalysisReport>(fp) {
                    self.counts.disk_hits += 1;
                    self.analyze.insert(fp, report);
                } else {
                    // The verifier cross-checks three layers against each
                    // other; all three materialize on this compute path.
                    self.ensure_parsed()?;
                    self.ensure_rtl()?;
                    self.ensure_netlist()?;
                    let report = crate::analyze::analyze_design(
                        self.parsed.value(),
                        self.rtl.value(),
                        self.netlist.value(),
                    );
                    self.counts.analyze += 1;
                    self.save_artifact(fp, &report);
                    self.analyze.insert(fp, report);
                }
            }
        }
        Ok(fp)
    }

    // ---- typed stage handles ---------------------------------------------

    /// The dimension-checked system model (frontend stage).
    pub fn parsed(&mut self) -> anyhow::Result<&SystemModel> {
        self.ensure_parsed()?;
        Ok(self.parsed.value())
    }

    /// The (optimized) Π-search result (analysis stage).
    pub fn pis(&mut self) -> anyhow::Result<&PiAnalysis> {
        self.ensure_pis()?;
        Ok(self.pis.value())
    }

    /// The generated RTL module (backend stage).
    pub fn rtl(&mut self) -> anyhow::Result<&PiModuleDesign> {
        self.ensure_rtl()?;
        Ok(self.rtl.value())
    }

    /// The LUT4-mapped netlist with resource accounting (implementation
    /// stage).
    pub fn netlist(&mut self) -> anyhow::Result<&MappedDesign> {
        self.ensure_netlist()?;
        Ok(self.netlist.value())
    }

    /// The RTL design together with its mapped netlist, from one
    /// consistent cache generation — for consumers (like gate-level
    /// simulation) that must never pair a stale design with a fresh
    /// netlist across a config change.
    pub fn rtl_and_netlist(&mut self) -> anyhow::Result<(&PiModuleDesign, &MappedDesign)> {
        // Both stages must be ensured explicitly: a warm netlist query is
        // lazy and does not materialize the RTL it was derived from.
        self.ensure_rtl()?;
        self.ensure_netlist()?;
        Ok((self.rtl.value(), self.netlist.value()))
    }

    /// Shared handle to the RTL stage artifact: the returned `Arc` is
    /// **the same allocation** the stage LRU holds, so any number of
    /// serving endpoints share one resident copy (single residency —
    /// tested in [`crate::coordinator::serveset`]).
    pub fn rtl_shared(&mut self) -> anyhow::Result<Arc<PiModuleDesign>> {
        self.ensure_rtl()?;
        Ok(Arc::clone(self.rtl.value()))
    }

    /// Shared handle to the mapped-netlist stage artifact (see
    /// [`Flow::rtl_shared`]). Ensures the RTL stage too, so the pair is
    /// from one consistent cache generation like
    /// [`Flow::rtl_and_netlist`].
    pub fn netlist_shared(&mut self) -> anyhow::Result<Arc<MappedDesign>> {
        self.ensure_rtl()?;
        self.ensure_netlist()?;
        Ok(Arc::clone(self.netlist.value()))
    }

    /// Static timing of the mapped netlist under the configured library.
    pub fn timing(&mut self) -> anyhow::Result<TimingReport> {
        self.ensure_timing()?;
        Ok(*self.timing.value())
    }

    /// Switching-activity power estimate under the configured stimulus.
    pub fn power(&mut self) -> anyhow::Result<PowerReport> {
        self.ensure_power()?;
        Ok(*self.power.value())
    }

    /// The emitted Verilog text.
    pub fn verilog(&mut self) -> anyhow::Result<&str> {
        self.ensure_verilog()?;
        Ok(self.verilog.value().as_str())
    }

    /// The static verifier's report over the compiled artifacts (all
    /// four [`crate::analyze`] passes except the shard-plan pre-flight,
    /// which keys on a fused plan — see
    /// [`super::fused::ensure_fused`] consumers). Memoized and persisted
    /// like every other stage; query it before serving to gate on
    /// [`AnalysisReport::has_errors`].
    pub fn analysis(&mut self) -> anyhow::Result<AnalysisReport> {
        self.ensure_analyze()?;
        Ok(self.analyze.value().clone())
    }

    /// Module latency in cycles under the configured scheduling policy
    /// (derived from the RTL stage; cheap, not cached).
    pub fn latency(&mut self) -> anyhow::Result<u64> {
        let policy = self.config.policy;
        Ok(rtl::module_latency(self.rtl()?, policy))
    }

    /// The width-shaped per-lane activity statistics of the power stage
    /// (cached with it — see [`PowerReport::spread`]); convert to mW at
    /// any clock with [`ActivitySpread`]'s model helpers.
    pub fn power_spread(&mut self) -> anyhow::Result<ActivitySpread> {
        Ok(self.power()?.spread)
    }
}
