//! Scoped-thread fan-out helpers shared by [`super::FlowSet`] and the
//! coordinator's power-request dispatch.
//!
//! Work is split into fixed-size chunks (e.g. 64-lane power batches, or
//! one flow per chunk) and consecutive chunks are grouped into one band
//! per worker thread, so thread count is bounded by the core count while
//! chunk boundaries — which often carry semantic meaning, like the 64
//! lanes of one word-parallel simulation pass — are never split. Output
//! order always matches input order, so parallel and sequential runs are
//! interchangeable.

use std::thread;

/// Worker threads to use for `units` independent units of work: one per
/// core, never more than the units themselves, at least one.
pub fn worker_count(units: usize) -> usize {
    let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min(units).max(1)
}

/// Map read-only chunks of `items` (each at most `chunk` long) through
/// `f` on scoped worker threads. `f` receives the global chunk index and
/// the chunk slice; the concatenated outputs preserve item order.
///
/// Falls back to a plain loop when one worker (or one chunk) suffices.
pub fn parallel_map_chunks<I, R, F>(items: &[I], chunk: usize, f: F) -> Vec<R>
where
    I: Sync,
    R: Send,
    F: Fn(usize, &[I]) -> Vec<R> + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    if items.is_empty() {
        return Vec::new();
    }
    let n_chunks = items.len().div_ceil(chunk);
    let workers = worker_count(n_chunks);
    if workers <= 1 {
        let mut out = Vec::with_capacity(items.len());
        for (ci, slice) in items.chunks(chunk).enumerate() {
            out.extend(f(ci, slice));
        }
        return out;
    }
    let chunks_per_band = n_chunks.div_ceil(workers);
    let band = chunks_per_band * chunk;
    let f = &f;
    let bands: Vec<Vec<R>> = thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(band)
            .enumerate()
            .map(|(bi, slice)| {
                s.spawn(move || {
                    let mut out = Vec::with_capacity(slice.len());
                    for (cj, ch) in slice.chunks(chunk).enumerate() {
                        out.extend(f(bi * chunks_per_band + cj, ch));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("flow worker panicked")).collect()
    });
    bands.into_iter().flatten().collect()
}

/// Like [`parallel_map_chunks`] but over mutable chunks, for workers
/// that own per-item state (e.g. [`super::Flow`] sessions memoizing
/// their stage caches in place).
pub fn parallel_map_chunks_mut<I, R, F>(items: &mut [I], chunk: usize, f: F) -> Vec<R>
where
    I: Send,
    R: Send,
    F: Fn(usize, &mut [I]) -> Vec<R> + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    if items.is_empty() {
        return Vec::new();
    }
    let n_chunks = items.len().div_ceil(chunk);
    let workers = worker_count(n_chunks);
    if workers <= 1 {
        let mut out = Vec::with_capacity(items.len());
        for (ci, slice) in items.chunks_mut(chunk).enumerate() {
            out.extend(f(ci, slice));
        }
        return out;
    }
    let chunks_per_band = n_chunks.div_ceil(workers);
    let band = chunks_per_band * chunk;
    let f = &f;
    let bands: Vec<Vec<R>> = thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks_mut(band)
            .enumerate()
            .map(|(bi, slice)| {
                s.spawn(move || {
                    let mut out = Vec::with_capacity(slice.len());
                    for (cj, ch) in slice.chunks_mut(chunk).enumerate() {
                        out.extend(f(bi * chunks_per_band + cj, ch));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("flow worker panicked")).collect()
    });
    bands.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_chunk_indices() {
        let items: Vec<u32> = (0..103).collect();
        for chunk in [1usize, 7, 64, 200] {
            let got = parallel_map_chunks(&items, chunk, |ci, slice| {
                // Verify the chunk index locates the slice.
                assert_eq!(slice[0] as usize, ci * chunk);
                slice.iter().map(|&v| v * 2).collect()
            });
            let want: Vec<u32> = items.iter().map(|&v| v * 2).collect();
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn mutable_variant_mutates_every_item_once() {
        let mut items = vec![0u32; 257];
        let got = parallel_map_chunks_mut(&mut items, 64, |_, slice| {
            slice.iter_mut().map(|v| {
                *v += 1;
                *v
            }).collect()
        });
        assert_eq!(got, vec![1u32; 257]);
        assert!(items.iter().all(|&v| v == 1));
    }

    #[test]
    fn empty_input_is_empty() {
        let got: Vec<u32> = parallel_map_chunks(&[] as &[u32], 8, |_, _| vec![1]);
        assert!(got.is_empty());
    }

    #[test]
    fn worker_count_bounds() {
        assert_eq!(worker_count(0), 1);
        assert_eq!(worker_count(1), 1);
        assert!(worker_count(1024) >= 1);
    }
}
