//! Persistent, fingerprint-keyed artifact store and the per-stage
//! in-memory LRU — what carries [`super::Flow`] memoization across
//! processes.
//!
//! A stage lookup consults, in order:
//!
//! 1. the **per-stage LRU** ([`Lru`], one per stage per `Flow`) — covers
//!    A/B sweeps whose return trips revisit a recent config;
//! 2. the **on-disk store** ([`ArtifactStore`], shared via `Arc` across
//!    sessions and threads) — covers warm starts of a new process;
//! 3. **compute**, followed by a best-effort write-back to the store.
//!
//! ## On-disk format (version [`STORE_FORMAT_VERSION`])
//!
//! One file per artifact at `<root>/<stage>/<fingerprint:016x>.art`:
//!
//! ```text
//! magic "DSARTFT\0" · u32 version · stage name · u64 fingerprint
//! · u64 FNV-1a checksum of payload · u64 payload length · payload
//! ```
//!
//! All integers are little-endian; strings are length-prefixed UTF-8;
//! `f64`s are raw IEEE-754 bits (artifacts round-trip *bit-exactly* —
//! canonicalization applies to fingerprints, not to stored values). The
//! payload is the stage artifact serialized by its [`Artifact`] impl.
//!
//! The store is a cache, so it is **corruption-tolerant by design**:
//! any header mismatch, failed checksum, truncation, or structural
//! validation error makes [`ArtifactStore::load`] return `None` and the
//! stage recomputes (and overwrites the bad entry) — it never panics or
//! fails the flow. Writers are concurrency-safe: entries are written to
//! a process-unique temp file and atomically renamed into place, so
//! parallel corpus drivers (and separate processes) can share one root.

use std::collections::VecDeque;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::config::StableHasher;
use super::session::PowerReport;
use crate::analyze::{AnalysisReport, DiagCode, Diagnostic, Locus};
use crate::fixedpoint::{MonOp, QFormat};
use crate::newton::{Symbol, SymbolKind, SystemModel};
use crate::pisearch::{PiAnalysis, PiGroup};
use crate::power::{ActivityReport, ActivitySpread, PowerModel};
use crate::rational::Rational;
use crate::rtl::{PiModuleDesign, PiUnit, Port};
use crate::shard::{FusedMember, FusedNetlist, RefineReport, ShardPlan};
use crate::synth::{NetId, Netlist, Node};
use crate::synth::techmap::MappedDesign;
use crate::timing::TimingReport;
use crate::units::{Dimension, NUM_BASE_DIMS};

/// Version of the on-disk entry format. Bump on any change to the header
/// layout, the payload encodings below, or the fingerprint function
/// ([`super::config::StableHasher`] canonicalization rules and the
/// fingerprint *domain* — which config fields feed each stage key) —
/// version mismatch makes every old entry a clean miss.
///
/// v2: the power artifact gained the width-shaped [`ActivitySpread`]
/// (and its fingerprint the SIMD lane width, `FlowConfig::lane_width`),
/// so v1 power entries have both a different payload layout and a
/// narrower key domain.
///
/// v3: added the `fused` stage ([`FusedArtifact`] — a fused multi-system
/// netlist keyed on its members' netlist fingerprints and the shard
/// count).
///
/// v4: the fused artifact carries its [`crate::shard::ShardPlan`]
/// (owner map + refinement report; cuts and loads are re-derived on
/// decode), and the fused fingerprint mixes in
/// [`crate::shard::PARTITIONER_VERSION`].
///
/// v5: added the `analyze` stage ([`crate::analyze::AnalysisReport`] —
/// the static verifier's findings, encoded as stable wire codes plus
/// locus and message; the stage fingerprint mixes in the verifier
/// version so pass changes invalidate cached reports).
pub const STORE_FORMAT_VERSION: u32 = 5;

const MAGIC: &[u8; 8] = b"DSARTFT\0";

/// The cached stages: the eight per-system stages of a [`super::Flow`]
/// plus the cross-system `fused` stage ([`super::fused::ensure_fused`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum StageKind {
    Parsed,
    Pis,
    Rtl,
    Netlist,
    Timing,
    Power,
    Verilog,
    Analyze,
    Fused,
}

impl StageKind {
    pub const ALL: [StageKind; 9] = [
        StageKind::Parsed,
        StageKind::Pis,
        StageKind::Rtl,
        StageKind::Netlist,
        StageKind::Timing,
        StageKind::Power,
        StageKind::Verilog,
        StageKind::Analyze,
        StageKind::Fused,
    ];

    /// Subdirectory (and header stage label) of this stage's entries.
    pub fn dir_name(self) -> &'static str {
        match self {
            StageKind::Parsed => "parsed",
            StageKind::Pis => "pis",
            StageKind::Rtl => "rtl",
            StageKind::Netlist => "netlist",
            StageKind::Timing => "timing",
            StageKind::Power => "power",
            StageKind::Verilog => "verilog",
            StageKind::Analyze => "analyze",
            StageKind::Fused => "fused",
        }
    }
}

// ---- canonical byte codec ------------------------------------------------

/// Append-only encoder for the canonical byte format.
#[derive(Default)]
pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    fn put_u16(&mut self, v: u16) {
        self.put_bytes(&v.to_le_bytes());
    }

    fn put_u32(&mut self, v: u32) {
        self.put_bytes(&v.to_le_bytes());
    }

    fn put_u64(&mut self, v: u64) {
        self.put_bytes(&v.to_le_bytes());
    }

    fn put_i64(&mut self, v: i64) {
        self.put_bytes(&v.to_le_bytes());
    }

    /// Raw IEEE-754 bits: stored artifacts round-trip bit-exactly.
    fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.put_bytes(s.as_bytes());
    }
}

/// Bounds-checked decoder; every read can fail cleanly on truncation.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow::anyhow!("artifact truncated"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn take_u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn take_bool(&mut self) -> anyhow::Result<bool> {
        match self.take_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(anyhow::anyhow!("bad bool byte {v}")),
        }
    }

    fn take_u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn take_u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn take_u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn take_i64(&mut self) -> anyhow::Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn take_f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    fn take_usize(&mut self) -> anyhow::Result<usize> {
        let v = self.take_u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("length {v} does not fit usize"))
    }

    /// A sequence length whose `len` elements (each at least
    /// `elem_floor` bytes) must fit in the remaining input — rejects
    /// corrupt lengths before any allocation sized by them.
    fn take_len(&mut self, elem_floor: usize) -> anyhow::Result<usize> {
        let len = self.take_usize()?;
        let remaining = self.buf.len() - self.pos;
        anyhow::ensure!(
            len <= remaining / elem_floor.max(1),
            "corrupt sequence length {len}"
        );
        Ok(len)
    }

    fn take_str(&mut self) -> anyhow::Result<String> {
        let len = self.take_len(1)?;
        Ok(std::str::from_utf8(self.take(len)?)?.to_string())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---- per-type encodings --------------------------------------------------

/// A stage artifact the store can persist. The encoding is hand-rolled
/// (no serde dependency) and versioned as a whole by
/// [`STORE_FORMAT_VERSION`].
pub(crate) trait Artifact: Sized {
    const STAGE: StageKind;
    fn encode(&self, w: &mut Writer);
    fn decode(r: &mut Reader<'_>) -> anyhow::Result<Self>;
}

fn put_rational(w: &mut Writer, v: Rational) {
    w.put_i64(v.num());
    w.put_i64(v.den());
}

fn take_rational(r: &mut Reader<'_>) -> anyhow::Result<Rational> {
    let num = r.take_i64()?;
    let den = r.take_i64()?;
    anyhow::ensure!(den > 0, "corrupt rational denominator {den}");
    Ok(Rational::new(num, den))
}

fn put_dimension(w: &mut Writer, d: &Dimension) {
    for &e in d.exps() {
        put_rational(w, e);
    }
}

fn take_dimension(r: &mut Reader<'_>) -> anyhow::Result<Dimension> {
    let mut exps = [Rational::ZERO; NUM_BASE_DIMS];
    for e in exps.iter_mut() {
        *e = take_rational(r)?;
    }
    Ok(Dimension::from_exps(exps))
}

fn put_str_vec(w: &mut Writer, items: &[String]) {
    w.put_usize(items.len());
    for s in items {
        w.put_str(s);
    }
}

fn take_str_vec(r: &mut Reader<'_>) -> anyhow::Result<Vec<String>> {
    let n = r.take_len(8)?;
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        items.push(r.take_str()?);
    }
    Ok(items)
}

fn put_i64_vec(w: &mut Writer, items: &[i64]) {
    w.put_usize(items.len());
    for &v in items {
        w.put_i64(v);
    }
}

fn take_i64_vec(r: &mut Reader<'_>) -> anyhow::Result<Vec<i64>> {
    let n = r.take_len(8)?;
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        items.push(r.take_i64()?);
    }
    Ok(items)
}

impl Artifact for SystemModel {
    const STAGE: StageKind = StageKind::Parsed;

    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_usize(self.symbols.len());
        for s in &self.symbols {
            w.put_str(&s.name);
            put_dimension(w, &s.dimension);
            w.put_u8(match s.kind {
                SymbolKind::Signal => 0,
                SymbolKind::Constant => 1,
            });
            match s.value {
                Some(v) => {
                    w.put_bool(true);
                    w.put_f64(v);
                }
                None => w.put_bool(false),
            }
        }
        put_str_vec(w, &self.relations);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<SystemModel> {
        let name = r.take_str()?;
        let n = r.take_len(1)?;
        let mut symbols = Vec::with_capacity(n);
        for _ in 0..n {
            let sym_name = r.take_str()?;
            let dimension = take_dimension(r)?;
            let kind = match r.take_u8()? {
                0 => SymbolKind::Signal,
                1 => SymbolKind::Constant,
                v => anyhow::bail!("bad symbol kind {v}"),
            };
            let value = if r.take_bool()? { Some(r.take_f64()?) } else { None };
            symbols.push(Symbol { name: sym_name, dimension, kind, value });
        }
        let relations = take_str_vec(r)?;
        Ok(SystemModel { name, symbols, relations })
    }
}

impl Artifact for PiAnalysis {
    const STAGE: StageKind = StageKind::Pis;

    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.system);
        put_str_vec(w, &self.symbols);
        w.put_usize(self.target);
        w.put_usize(self.groups.len());
        for g in &self.groups {
            put_i64_vec(w, &g.exponents);
        }
        w.put_usize(self.target_group);
        w.put_usize(self.rank);
        w.put_usize(self.nonparticipating.len());
        for &i in &self.nonparticipating {
            w.put_usize(i);
        }
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<PiAnalysis> {
        let system = r.take_str()?;
        let symbols = take_str_vec(r)?;
        let k = symbols.len();
        let target = r.take_usize()?;
        anyhow::ensure!(target < k, "target index {target} out of range");
        let n_groups = r.take_len(8)?;
        let mut groups = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            let exponents = take_i64_vec(r)?;
            anyhow::ensure!(exponents.len() == k, "group arity mismatch");
            groups.push(PiGroup { exponents });
        }
        let target_group = r.take_usize()?;
        anyhow::ensure!(target_group < groups.len(), "target group out of range");
        let rank = r.take_usize()?;
        let n_np = r.take_len(8)?;
        let mut nonparticipating = Vec::with_capacity(n_np);
        for _ in 0..n_np {
            let i = r.take_usize()?;
            anyhow::ensure!(i < k, "non-participating index {i} out of range");
            nonparticipating.push(i);
        }
        Ok(PiAnalysis { system, symbols, target, groups, target_group, rank, nonparticipating })
    }
}

fn put_monop(w: &mut Writer, op: &MonOp) {
    match op {
        MonOp::Load(i) => {
            w.put_u8(0);
            w.put_usize(*i);
        }
        MonOp::LoadOne => w.put_u8(1),
        MonOp::Mul(i) => {
            w.put_u8(2);
            w.put_usize(*i);
        }
        MonOp::Div(i) => {
            w.put_u8(3);
            w.put_usize(*i);
        }
    }
}

fn take_monop(r: &mut Reader<'_>, n_ports: usize) -> anyhow::Result<MonOp> {
    // LoadOne references no port, so only the indexed ops are
    // bounds-checked.
    let op = match r.take_u8()? {
        0 => MonOp::Load(r.take_usize()?),
        1 => return Ok(MonOp::LoadOne),
        2 => MonOp::Mul(r.take_usize()?),
        3 => MonOp::Div(r.take_usize()?),
        t => anyhow::bail!("bad monomial op tag {t}"),
    };
    if let MonOp::Load(i) | MonOp::Mul(i) | MonOp::Div(i) = &op {
        anyhow::ensure!(*i < n_ports, "monomial op index {i} out of range");
    }
    Ok(op)
}

impl Artifact for PiModuleDesign {
    const STAGE: StageKind = StageKind::Rtl;

    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_str(&self.system);
        w.put_u32(self.q.int_bits);
        w.put_u32(self.q.frac_bits);
        w.put_usize(self.ports.len());
        for p in &self.ports {
            w.put_str(&p.name);
            w.put_usize(p.symbol_index);
        }
        w.put_usize(self.units.len());
        for u in &self.units {
            w.put_str(&u.name);
            put_i64_vec(w, &u.exponents);
            w.put_usize(u.ops.len());
            for op in &u.ops {
                put_monop(w, op);
            }
            w.put_str(&u.expr);
        }
        w.put_usize(self.target_unit);
        put_str_vec(w, &self.dropped_symbols);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<PiModuleDesign> {
        let name = r.take_str()?;
        let system = r.take_str()?;
        let int_bits = r.take_u32()?;
        let frac_bits = r.take_u32()?;
        let n_ports = r.take_len(8)?;
        let mut ports = Vec::with_capacity(n_ports);
        for _ in 0..n_ports {
            ports.push(Port { name: r.take_str()?, symbol_index: r.take_usize()? });
        }
        let n_units = r.take_len(8)?;
        let mut units = Vec::with_capacity(n_units);
        for _ in 0..n_units {
            let unit_name = r.take_str()?;
            let exponents = take_i64_vec(r)?;
            anyhow::ensure!(exponents.len() == n_ports, "unit arity mismatch");
            let n_ops = r.take_len(1)?;
            let mut ops = Vec::with_capacity(n_ops);
            for _ in 0..n_ops {
                ops.push(take_monop(r, n_ports)?);
            }
            let expr = r.take_str()?;
            units.push(PiUnit { name: unit_name, exponents, ops, expr });
        }
        let target_unit = r.take_usize()?;
        anyhow::ensure!(target_unit < units.len(), "target unit out of range");
        let dropped_symbols = take_str_vec(r)?;
        Ok(PiModuleDesign {
            name,
            system,
            q: QFormat::new(int_bits, frac_bits),
            ports,
            units,
            target_unit,
            dropped_symbols,
        })
    }
}

fn put_buses(w: &mut Writer, buses: &[(String, Vec<NetId>)]) {
    w.put_usize(buses.len());
    for (name, bits) in buses {
        w.put_str(name);
        w.put_usize(bits.len());
        for &b in bits {
            w.put_u32(b);
        }
    }
}

fn take_buses(r: &mut Reader<'_>, n_nodes: usize) -> anyhow::Result<Vec<(String, Vec<NetId>)>> {
    let n = r.take_len(8)?;
    let mut buses = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.take_str()?;
        let n_bits = r.take_len(4)?;
        let mut bits = Vec::with_capacity(n_bits);
        for _ in 0..n_bits {
            let b = r.take_u32()?;
            anyhow::ensure!((b as usize) < n_nodes, "bus bit {b} out of range");
            bits.push(b);
        }
        buses.push((name, bits));
    }
    Ok(buses)
}

fn put_netlist(w: &mut Writer, nl: &Netlist) {
    w.put_usize(nl.len());
    for (_, node) in nl.nodes() {
        match node {
            Node::Const(v) => {
                w.put_u8(0);
                w.put_bool(*v);
            }
            Node::Input(name) => {
                w.put_u8(1);
                w.put_str(name);
            }
            Node::Lut { ins, tt } => {
                w.put_u8(2);
                w.put_usize(ins.len());
                for &i in ins {
                    w.put_u32(i);
                }
                w.put_u16(*tt);
            }
            Node::Dff { d, init } => {
                w.put_u8(3);
                w.put_u32(*d);
                w.put_bool(*init);
            }
        }
    }
    put_buses(w, nl.outputs());
    put_buses(w, &nl.input_buses);
}

fn take_netlist(r: &mut Reader<'_>) -> anyhow::Result<Netlist> {
    let n = r.take_len(1)?;
    let mut nodes = Vec::with_capacity(n);
    for id in 0..n {
        let node = match r.take_u8()? {
            0 => Node::Const(r.take_bool()?),
            1 => Node::Input(r.take_str()?),
            2 => {
                let arity = r.take_len(4)?;
                anyhow::ensure!((1..=4).contains(&arity), "bad LUT arity {arity}");
                let mut ins = Vec::with_capacity(arity);
                for _ in 0..arity {
                    let i = r.take_u32()?;
                    // The topological invariant the simulators rely on.
                    anyhow::ensure!((i as usize) < id, "LUT {id} reads forward net {i}");
                    ins.push(i);
                }
                Node::Lut { ins, tt: r.take_u16()? }
            }
            3 => Node::Dff { d: r.take_u32()?, init: r.take_bool()? },
            t => anyhow::bail!("bad node tag {t}"),
        };
        nodes.push(node);
    }
    // DFF data inputs may legally point forward; validate after the fact.
    for node in &nodes {
        if let Node::Dff { d, .. } = node {
            anyhow::ensure!((*d as usize) < n, "DFF input {d} out of range");
        }
    }
    let outputs = take_buses(r, n)?;
    let input_buses = take_buses(r, n)?;
    Ok(Netlist::from_parts(nodes, outputs, input_buses))
}

impl Artifact for MappedDesign {
    const STAGE: StageKind = StageKind::Netlist;

    fn encode(&self, w: &mut Writer) {
        put_netlist(w, &self.netlist);
        w.put_usize(self.lut4_cells);
        w.put_usize(self.luts);
        w.put_usize(self.dffs);
        w.put_usize(self.gate_count);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<MappedDesign> {
        Ok(MappedDesign {
            netlist: take_netlist(r)?,
            lut4_cells: r.take_usize()?,
            luts: r.take_usize()?,
            dffs: r.take_usize()?,
            gate_count: r.take_usize()?,
        })
    }
}

impl Artifact for TimingReport {
    const STAGE: StageKind = StageKind::Timing;

    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.depth);
        w.put_f64(self.period_ns);
        w.put_f64(self.fmax_mhz);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<TimingReport> {
        Ok(TimingReport {
            depth: r.take_u32()?,
            period_ns: r.take_f64()?,
            fmax_mhz: r.take_f64()?,
        })
    }
}

impl Artifact for PowerReport {
    const STAGE: StageKind = StageKind::Power;

    fn encode(&self, w: &mut Writer) {
        w.put_f64(self.activity.toggles_per_cycle);
        w.put_u64(self.activity.cycles);
        w.put_u32(self.activity.activations);
        w.put_u32(self.spread.lanes);
        w.put_f64(self.spread.mean_tpc);
        w.put_f64(self.spread.std_tpc);
        w.put_f64(self.spread.min_tpc);
        w.put_f64(self.spread.max_tpc);
        w.put_f64(self.model.vdd);
        w.put_f64(self.model.c_eff);
        w.put_f64(self.model.p_static);
        w.put_f64(self.mw_6mhz);
        w.put_f64(self.mw_12mhz);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<PowerReport> {
        Ok(PowerReport {
            activity: ActivityReport {
                toggles_per_cycle: r.take_f64()?,
                cycles: r.take_u64()?,
                activations: r.take_u32()?,
            },
            spread: ActivitySpread {
                lanes: r.take_u32()?,
                mean_tpc: r.take_f64()?,
                std_tpc: r.take_f64()?,
                min_tpc: r.take_f64()?,
                max_tpc: r.take_f64()?,
            },
            model: PowerModel {
                vdd: r.take_f64()?,
                c_eff: r.take_f64()?,
                p_static: r.take_f64()?,
            },
            mw_6mhz: r.take_f64()?,
            mw_12mhz: r.take_f64()?,
        })
    }
}

impl Artifact for AnalysisReport {
    const STAGE: StageKind = StageKind::Analyze;

    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.system);
        w.put_usize(self.diagnostics.len());
        for d in &self.diagnostics {
            // Pass and severity are derived from the code on decode, so
            // only the stable wire id is stored.
            w.put_u16(d.code.wire());
            match d.locus {
                Locus::Module => w.put_u8(0),
                Locus::Net(n) => {
                    w.put_u8(1);
                    w.put_u32(n);
                }
                Locus::Unit(u) => {
                    w.put_u8(2);
                    w.put_usize(u);
                }
                Locus::Shard(s) => {
                    w.put_u8(3);
                    w.put_u16(s);
                }
            }
            w.put_str(&d.message);
        }
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<AnalysisReport> {
        let system = r.take_str()?;
        let n = r.take_len(3)?;
        let mut diagnostics = Vec::with_capacity(n);
        for _ in 0..n {
            let wire = r.take_u16()?;
            let code = DiagCode::from_wire(wire)
                .ok_or_else(|| anyhow::anyhow!("unknown diagnostic code {wire}"))?;
            let locus = match r.take_u8()? {
                0 => Locus::Module,
                1 => Locus::Net(r.take_u32()?),
                2 => Locus::Unit(r.take_usize()?),
                3 => Locus::Shard(r.take_u16()?),
                t => anyhow::bail!("bad locus tag {t}"),
            };
            let message = r.take_str()?;
            diagnostics.push(Diagnostic::new(code, locus, message));
        }
        Ok(AnalysisReport { system, diagnostics })
    }
}

impl Artifact for String {
    const STAGE: StageKind = StageKind::Verilog;

    fn encode(&self, w: &mut Writer) {
        w.put_str(self);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<String> {
        r.take_str()
    }
}

/// The cached cross-system `fused` stage: a [`FusedNetlist`] (one module
/// merging N member netlists) together with the member netlist
/// fingerprints it was fused **from, in fuse order**, and the shard
/// count it was keyed under. The store key hashes the member
/// fingerprints *sorted* (membership, not order), so a loader must check
/// `member_fps` against its requested order — net numbering depends on
/// it — and recompute on mismatch (see [`super::fused::ensure_fused`]).
pub struct FusedArtifact {
    /// The fused netlist with its per-member scatter index.
    pub fused: FusedNetlist,
    /// The refined shard plan for `fused` at `shards` shards. Encoded
    /// as the owner map plus the refinement report; cut lists and
    /// per-shard loads are re-derived on decode, so a loaded plan is
    /// always self-consistent with the netlist.
    pub plan: ShardPlan,
    /// Netlist-stage fingerprints of the members, in fuse order.
    pub member_fps: Vec<u64>,
    /// Shard count the artifact was keyed under.
    pub shards: usize,
}

impl Artifact for FusedArtifact {
    const STAGE: StageKind = StageKind::Fused;

    fn encode(&self, w: &mut Writer) {
        put_netlist(w, &self.fused.netlist);
        w.put_usize(self.fused.members.len());
        for m in &self.fused.members {
            w.put_str(&m.prefix);
            w.put_u32(m.net_range.0);
            w.put_u32(m.net_range.1);
            w.put_usize(m.gates);
        }
        w.put_usize(self.member_fps.len());
        for &fp in &self.member_fps {
            w.put_u64(fp);
        }
        w.put_usize(self.shards);
        w.put_usize(self.plan.shards);
        w.put_usize(self.plan.owner.len());
        for &o in &self.plan.owner {
            w.put_u32(u32::from(o));
        }
        w.put_usize(self.plan.refinement.initial_cut_cost);
        w.put_usize(self.plan.refinement.refined_cut_cost);
        w.put_usize(self.plan.refinement.cluster_moves);
        w.put_usize(self.plan.refinement.level0_moves);
        w.put_usize(self.plan.refinement.sweeps);
    }

    fn decode(r: &mut Reader<'_>) -> anyhow::Result<FusedArtifact> {
        let netlist = take_netlist(r)?;
        let n_members = r.take_len(8)?;
        anyhow::ensure!(n_members >= 1, "fused artifact has no members");
        anyhow::ensure!(n_members <= u16::MAX as usize, "member count {n_members} too large");
        let mut members = Vec::with_capacity(n_members);
        let mut expect = 0u32;
        for _ in 0..n_members {
            let prefix = r.take_str()?;
            let lo = r.take_u32()?;
            let hi = r.take_u32()?;
            let gates = r.take_usize()?;
            // Ranges must tile [0, len) contiguously — the invariant
            // `FusedNetlist::from_parts` asserts; validate here so a
            // corrupt entry is a miss, not a panic.
            anyhow::ensure!(lo == expect && hi >= lo, "member range [{lo},{hi}) does not tile");
            expect = hi;
            members.push(FusedMember { prefix, net_range: (lo, hi), gates });
        }
        anyhow::ensure!(
            expect as usize == netlist.len(),
            "member ranges cover {expect} of {} nets",
            netlist.len()
        );
        let n_fps = r.take_len(8)?;
        anyhow::ensure!(n_fps == n_members, "fingerprint count mismatch");
        let mut member_fps = Vec::with_capacity(n_fps);
        for _ in 0..n_fps {
            member_fps.push(r.take_u64()?);
        }
        let shards = r.take_usize()?;
        let plan_shards = r.take_usize()?;
        anyhow::ensure!(
            plan_shards == shards.max(1),
            "plan shard count {plan_shards} does not match artifact key {shards}"
        );
        let n_owner = r.take_len(4)?;
        anyhow::ensure!(
            n_owner == netlist.len(),
            "owner map covers {n_owner} of {} nets",
            netlist.len()
        );
        let mut owner = Vec::with_capacity(n_owner);
        for _ in 0..n_owner {
            let o = r.take_u32()?;
            anyhow::ensure!(o < plan_shards as u32, "owner {o} out of range");
            owner.push(o as u16);
        }
        let refinement = RefineReport {
            initial_cut_cost: r.take_usize()?,
            refined_cut_cost: r.take_usize()?,
            cluster_moves: r.take_usize()?,
            level0_moves: r.take_usize()?,
            sweeps: r.take_usize()?,
        };
        let fused = FusedNetlist::from_parts(netlist, members);
        // Re-derive cut lists and loads from the owner map: the loaded
        // plan is self-consistent with the netlist by construction.
        let mut plan = ShardPlan::from_owner(&fused, plan_shards, owner);
        anyhow::ensure!(
            plan.cut_cost() == refinement.refined_cut_cost,
            "stored refinement report disagrees with re-derived cuts"
        );
        plan.refinement = refinement;
        Ok(FusedArtifact { fused, plan, member_fps, shards })
    }
}

// ---- entry framing -------------------------------------------------------

fn encode_entry<A: Artifact>(fp: u64, artifact: &A) -> Vec<u8> {
    let mut payload = Writer::default();
    artifact.encode(&mut payload);
    let payload = payload.into_bytes();
    let checksum = StableHasher::new().bytes(&payload).finish();
    let mut w = Writer::default();
    w.put_bytes(MAGIC);
    w.put_u32(STORE_FORMAT_VERSION);
    w.put_str(A::STAGE.dir_name());
    w.put_u64(fp);
    w.put_u64(checksum);
    w.put_usize(payload.len());
    w.put_bytes(&payload);
    w.into_bytes()
}

fn decode_entry<A: Artifact>(fp: u64, bytes: &[u8]) -> anyhow::Result<A> {
    let mut r = Reader::new(bytes);
    anyhow::ensure!(r.take(MAGIC.len())? == &MAGIC[..], "bad magic");
    let version = r.take_u32()?;
    anyhow::ensure!(version == STORE_FORMAT_VERSION, "format version {version}");
    let stage = r.take_str()?;
    anyhow::ensure!(stage == A::STAGE.dir_name(), "stage mismatch `{stage}`");
    let entry_fp = r.take_u64()?;
    anyhow::ensure!(entry_fp == fp, "fingerprint mismatch");
    let checksum = r.take_u64()?;
    let len = r.take_len(1)?;
    let payload = r.take(len)?;
    anyhow::ensure!(r.done(), "trailing bytes after payload");
    anyhow::ensure!(
        StableHasher::new().bytes(payload).finish() == checksum,
        "checksum mismatch"
    );
    let mut pr = Reader::new(payload);
    let artifact = A::decode(&mut pr)?;
    anyhow::ensure!(pr.done(), "trailing bytes in payload");
    Ok(artifact)
}

// ---- the store -----------------------------------------------------------

/// Per-stage entry/byte counts of a store root (see
/// [`ArtifactStore::stats`]).
#[derive(Clone, Debug)]
pub struct StageStats {
    pub stage: &'static str,
    pub entries: u64,
    pub bytes: u64,
}

/// Aggregate statistics of an [`ArtifactStore`].
#[derive(Clone, Debug)]
pub struct StoreStats {
    pub stages: Vec<StageStats>,
}

/// Outcome of one [`ArtifactStore::gc`] pass.
#[derive(Clone, Copy, Debug)]
pub struct GcReport {
    /// Entries deleted, oldest-first.
    pub removed_entries: u64,
    /// Bytes those entries occupied.
    pub removed_bytes: u64,
    /// Entries remaining after the pass.
    pub kept_entries: u64,
    /// Bytes remaining after the pass (≤ the requested cap unless the
    /// cap is smaller than the newest single entry set that survived
    /// deletion failures).
    pub kept_bytes: u64,
}

impl StoreStats {
    pub fn total_entries(&self) -> u64 {
        self.stages.iter().map(|s| s.entries).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.bytes).sum()
    }
}

/// The persistent, fingerprint-keyed artifact store (see module docs for
/// the on-disk format and the corruption/concurrency contract). Shared
/// across sessions and worker threads via `Arc`.
pub struct ArtifactStore {
    root: PathBuf,
    /// Distinguishes concurrent temp files within one process (the pid
    /// distinguishes processes).
    seq: AtomicU64,
}

impl ArtifactStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> anyhow::Result<ArtifactStore> {
        let root = root.as_ref().to_path_buf();
        for stage in StageKind::ALL {
            fs::create_dir_all(root.join(stage.dir_name()))?;
        }
        Ok(ArtifactStore { root, seq: AtomicU64::new(0) })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn entry_path(&self, stage: StageKind, fp: u64) -> PathBuf {
        self.root.join(stage.dir_name()).join(format!("{fp:016x}.art"))
    }

    /// Load the artifact stored under `fp`, or `None` when the entry is
    /// absent, unreadable, or fails any validation — a cache miss, never
    /// an error. A successful load touches the entry's mtime so
    /// [`ArtifactStore::gc`] sees last *use*, not last write — atime is
    /// unreliable under the common `relatime`/`noatime` mounts.
    pub(crate) fn load<A: Artifact>(&self, fp: u64) -> Option<A> {
        let path = self.entry_path(A::STAGE, fp);
        let bytes = fs::read(&path).ok()?;
        let artifact = decode_entry::<A>(fp, &bytes).ok()?;
        let _ = fs::File::options()
            .write(true)
            .open(&path)
            .and_then(|f| f.set_modified(std::time::SystemTime::now()));
        Some(artifact)
    }

    /// Persist an artifact under `fp` via temp-file + atomic rename, so
    /// concurrent writers (threads or processes) never expose a torn
    /// entry.
    pub(crate) fn save<A: Artifact>(&self, fp: u64, artifact: &A) -> anyhow::Result<()> {
        let bytes = encode_entry(fp, artifact);
        let path = self.entry_path(A::STAGE, fp);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            self.seq.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, &bytes)?;
        match fs::rename(&tmp, &path) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = fs::remove_file(&tmp);
                Err(e.into())
            }
        }
    }

    /// Per-stage entry counts and byte sizes.
    pub fn stats(&self) -> anyhow::Result<StoreStats> {
        let mut stages = Vec::with_capacity(StageKind::ALL.len());
        for stage in StageKind::ALL {
            let mut entries = 0u64;
            let mut bytes = 0u64;
            if let Ok(rd) = fs::read_dir(self.root.join(stage.dir_name())) {
                for de in rd.flatten() {
                    let path = de.path();
                    if path.extension().map(|e| e == "art").unwrap_or(false) {
                        entries += 1;
                        bytes += de.metadata().map(|m| m.len()).unwrap_or(0);
                    }
                }
            }
            stages.push(StageStats { stage: stage.dir_name(), entries, bytes });
        }
        Ok(StoreStats { stages })
    }

    /// Size-capped pruning: delete entries **least-recently-used first**
    /// until the store's total entry bytes fit under `max_bytes`. "Use"
    /// is the entry's mtime — bumped by [`ArtifactStore::load`] on every
    /// successful read precisely because atime is stale under `relatime`
    /// and frozen under `noatime` mounts. The store is a cache, so
    /// eviction is always safe — evicted artifacts recompute on next
    /// demand. Returns what was removed and what remains.
    pub fn gc(&self, max_bytes: u64) -> anyhow::Result<GcReport> {
        // Stale temp files (a writer that died between write and rename)
        // are invisible to `load` but still occupy disk; sweep any older
        // than an hour — no live writer holds a temp file that long —
        // so the byte cap governs actual directory usage.
        const TMP_MAX_AGE: std::time::Duration = std::time::Duration::from_secs(3600);
        let now = std::time::SystemTime::now();
        let mut entries: Vec<(std::time::SystemTime, u64, PathBuf)> = Vec::new();
        let mut kept_bytes = 0u64;
        for stage in StageKind::ALL {
            if let Ok(rd) = fs::read_dir(self.root.join(stage.dir_name())) {
                for de in rd.flatten() {
                    let path = de.path();
                    let Ok(md) = de.metadata() else { continue };
                    let stamp = md
                        .modified()
                        .or_else(|_| md.accessed())
                        .unwrap_or(std::time::UNIX_EPOCH);
                    if !path.extension().map(|e| e == "art").unwrap_or(false) {
                        if path.is_file()
                            && now.duration_since(stamp).map(|a| a > TMP_MAX_AGE).unwrap_or(false)
                        {
                            let _ = fs::remove_file(&path);
                        }
                        continue;
                    }
                    kept_bytes += md.len();
                    entries.push((stamp, md.len(), path));
                }
            }
        }
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let mut report = GcReport {
            removed_entries: 0,
            removed_bytes: 0,
            kept_entries: entries.len() as u64,
            kept_bytes,
        };
        for (_, len, path) in entries {
            if report.kept_bytes <= max_bytes {
                break;
            }
            if fs::remove_file(&path).is_ok() {
                report.removed_entries += 1;
                report.removed_bytes += len;
                report.kept_entries -= 1;
                report.kept_bytes -= len;
            }
        }
        Ok(report)
    }

    /// Delete every entry (and stray temp file); returns how many files
    /// were removed.
    pub fn clear(&self) -> anyhow::Result<u64> {
        let mut removed = 0u64;
        for stage in StageKind::ALL {
            if let Ok(rd) = fs::read_dir(self.root.join(stage.dir_name())) {
                for de in rd.flatten() {
                    let path = de.path();
                    if path.is_file() && fs::remove_file(&path).is_ok() {
                        removed += 1;
                    }
                }
            }
        }
        Ok(removed)
    }
}

// ---- per-stage LRU -------------------------------------------------------

/// Outcome of promoting a fingerprint in a per-stage [`Lru`].
pub(crate) enum LruHit {
    /// The front entry already matched (repeat query, no state change).
    Fresh,
    /// Found deeper in the cache and moved to the front (e.g. a sweep's
    /// return trip).
    Promoted,
    /// Not cached.
    Miss,
}

/// A small per-stage LRU keyed on stage fingerprints. The front entry is
/// always the artifact of the most recently ensured fingerprint — the
/// one the stage accessors borrow.
pub(crate) struct Lru<T> {
    entries: VecDeque<(u64, T)>,
    cap: usize,
}

impl<T> Lru<T> {
    pub fn new(cap: usize) -> Lru<T> {
        assert!(cap >= 1, "LRU capacity must be positive");
        Lru { entries: VecDeque::new(), cap }
    }

    /// Move the entry for `fp` (if cached) to the front.
    pub fn promote(&mut self, fp: u64) -> LruHit {
        match self.entries.iter().position(|(k, _)| *k == fp) {
            Some(0) => LruHit::Fresh,
            Some(i) => {
                let entry = self.entries.remove(i).expect("position is in range");
                self.entries.push_front(entry);
                LruHit::Promoted
            }
            None => LruHit::Miss,
        }
    }

    /// Insert at the front, evicting the least recently used entry
    /// beyond capacity.
    pub fn insert(&mut self, fp: u64, value: T) {
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == fp) {
            self.entries.remove(i);
        }
        self.entries.push_front((fp, value));
        self.entries.truncate(self.cap);
    }

    /// The most recently ensured artifact.
    pub fn value(&self) -> &T {
        self.entries.front().map(|(_, v)| v).expect("stage was just ensured")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("dimsynth-store-unit-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn timing_report_roundtrips_bit_exactly() {
        let dir = tmpdir("timing");
        let store = ArtifactStore::open(&dir).unwrap();
        let report = TimingReport { depth: 42, period_ns: 17.25, fmax_mhz: 57.971 };
        store.save(0xFEED, &report).unwrap();
        let back: TimingReport = store.load(0xFEED).unwrap();
        assert_eq!(back.depth, 42);
        assert_eq!(back.period_ns.to_bits(), report.period_ns.to_bits());
        assert_eq!(back.fmax_mhz.to_bits(), report.fmax_mhz.to_bits());
        assert!(store.load::<TimingReport>(0xBEEF).is_none(), "absent fp must miss");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stage_and_fingerprint_mismatches_are_misses() {
        let dir = tmpdir("mismatch");
        let store = ArtifactStore::open(&dir).unwrap();
        store.save(1, &"module m; endmodule".to_string()).unwrap();
        assert!(store.load::<String>(1).is_some());
        // A verilog entry must not decode as a timing artifact even when
        // a file with the right name exists.
        fs::copy(
            store.entry_path(StageKind::Verilog, 1),
            store.entry_path(StageKind::Timing, 1),
        )
        .unwrap();
        assert!(store.load::<TimingReport>(1).is_none());
        // Nor under a renamed (wrong) fingerprint.
        fs::copy(
            store.entry_path(StageKind::Verilog, 1),
            store.entry_path(StageKind::Verilog, 2),
        )
        .unwrap();
        assert!(store.load::<String>(2).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_bytes_are_misses_not_panics() {
        let dir = tmpdir("corrupt");
        let store = ArtifactStore::open(&dir).unwrap();
        let text = "x".repeat(256);
        store.save(9, &text).unwrap();
        let path = store.entry_path(StageKind::Verilog, 9);
        let pristine = fs::read(&path).unwrap();

        let mut flipped = pristine.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x5A;
        fs::write(&path, &flipped).unwrap();
        assert!(store.load::<String>(9).is_none(), "bit flip must fail the checksum");

        fs::write(&path, &pristine[..pristine.len() / 2]).unwrap();
        assert!(store.load::<String>(9).is_none(), "truncation must miss");

        fs::write(&path, b"").unwrap();
        assert!(store.load::<String>(9).is_none(), "empty file must miss");

        fs::write(&path, &pristine).unwrap();
        assert_eq!(store.load::<String>(9).unwrap(), text);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn analysis_report_roundtrips_every_locus() {
        let dir = tmpdir("analysis");
        let store = ArtifactStore::open(&dir).unwrap();
        let report = AnalysisReport {
            system: "pendulum".into(),
            diagnostics: vec![
                Diagnostic::new(DiagCode::CombLoop, Locus::Net(7), "cycle 5 -> 7 -> 5"),
                Diagnostic::new(DiagCode::QSaturation, Locus::Unit(2), "pi_2 may saturate"),
                Diagnostic::new(DiagCode::MissingCut, Locus::Shard(3), "net 9 uncovered"),
                Diagnostic::new(DiagCode::OwnerMapMalformed, Locus::Module, "short owner map"),
            ],
        };
        store.save(0xA11A, &report).unwrap();
        let back: AnalysisReport = store.load(0xA11A).unwrap();
        assert_eq!(back, report);
        // A clean report (the common case) round-trips too.
        let clean = AnalysisReport { system: "beam".into(), diagnostics: Vec::new() };
        store.save(0xC1EA, &clean).unwrap();
        assert_eq!(store.load::<AnalysisReport>(0xC1EA).unwrap(), clean);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_and_clear_cover_all_stages() {
        let dir = tmpdir("stats");
        let store = ArtifactStore::open(&dir).unwrap();
        store.save(1, &"a".to_string()).unwrap();
        store.save(2, &"b".to_string()).unwrap();
        store
            .save(3, &TimingReport { depth: 1, period_ns: 2.0, fmax_mhz: 500.0 })
            .unwrap();
        let stats = store.stats().unwrap();
        assert_eq!(stats.total_entries(), 3);
        assert!(stats.total_bytes() > 0);
        assert_eq!(stats.stages.len(), StageKind::ALL.len());
        assert_eq!(store.clear().unwrap(), 3);
        assert_eq!(store.stats().unwrap().total_entries(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Pin an entry's mtime to a precise instant (the test must not
    /// depend on real sleeps or filesystem timestamp granularity).
    fn stamp(store: &ArtifactStore, stage: StageKind, fp: u64, t: std::time::SystemTime) {
        fs::File::options()
            .write(true)
            .open(store.entry_path(stage, fp))
            .and_then(|f| f.set_modified(t))
            .unwrap();
    }

    #[test]
    fn gc_prunes_least_recently_used_to_byte_cap() {
        let dir = tmpdir("gc");
        let store = ArtifactStore::open(&dir).unwrap();
        // Three entries with explicitly spaced last-use stamps, oldest
        // first (save order is irrelevant).
        let base = std::time::SystemTime::now() - std::time::Duration::from_secs(100);
        for (fp, text) in [(1u64, "a"), (2, "b"), (3, "c")] {
            store.save(fp, &text.repeat(200)).unwrap();
            stamp(&store, StageKind::Verilog, fp, base + std::time::Duration::from_secs(fp));
        }
        // Re-reading the oldest entry marks it as recently used (load
        // touches mtime — atime would be stale under relatime mounts);
        // it becomes the newest stamp of the three.
        assert!(store.load::<String>(1).is_some());
        let total = store.stats().unwrap().total_bytes();
        let one = total / 3;

        // Cap that fits roughly one entry: the two least recently USED
        // entries go; the just-read oldest-written entry survives.
        let report = store.gc(one).unwrap();
        assert_eq!(report.removed_entries, 2, "{report:?}");
        assert_eq!(report.kept_entries, 1, "{report:?}");
        assert!(report.kept_bytes <= one, "{report:?}");
        assert!(store.load::<String>(1).is_some(), "recently used entry must survive");
        assert!(store.load::<String>(2).is_none());
        assert!(store.load::<String>(3).is_none());

        // A cap larger than the store is a no-op.
        let report = store.gc(u64::MAX).unwrap();
        assert_eq!(report.removed_entries, 0);
        assert_eq!(report.kept_entries, 1);

        // Zero cap empties the store entirely.
        let report = store.gc(0).unwrap();
        assert_eq!(report.kept_entries, 0);
        assert_eq!(report.kept_bytes, 0);
        assert_eq!(store.stats().unwrap().total_entries(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_promotes_and_evicts() {
        let mut lru: Lru<u32> = Lru::new(2);
        assert!(matches!(lru.promote(1), LruHit::Miss));
        lru.insert(1, 10);
        assert!(matches!(lru.promote(1), LruHit::Fresh));
        lru.insert(2, 20);
        assert!(matches!(lru.promote(1), LruHit::Promoted));
        assert_eq!(lru.value(), &10);
        lru.insert(3, 30); // evicts 2, the least recently used
        assert!(matches!(lru.promote(2), LruHit::Miss));
        assert!(matches!(lru.promote(1), LruHit::Promoted));
        assert!(matches!(lru.promote(3), LruHit::Promoted));
    }
}
