//! PJRT runtime: load AOT-compiled XLA artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them from the Rust request path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire ML-execution surface of the deployed binary. See
//! /opt/xla-example/load_hlo for the interchange rationale (HLO *text*,
//! not serialized protos — jax ≥ 0.5 emits 64-bit instruction ids the
//! bundled XLA rejects).

pub mod engine;

pub use engine::{Engine, Executable};
