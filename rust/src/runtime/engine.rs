//! The executable cache and literal conversion helpers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// One compiled artifact, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with the given input literals; returns the decomposed
    /// output tuple (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: readback failed: {e:?}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("{}: tuple decompose failed: {e:?}", self.name))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Batched entry point: run a fixed-batch-size executable over any
    /// number of samples by chunking into `batch_rows`-row windows
    /// (zero-padded tail), reading `out_width` int32 values per sample
    /// from output 0. This is the executable-side counterpart of the
    /// lane-wide dispatch in [`crate::coordinator::Pipeline`];
    /// `batch_rows` is baked into the AOT artifact's input shape (the
    /// `*_b64` executables are 64-row), independent of the gate-level
    /// simulator's runtime-selected SIMD lane width.
    pub fn run_batched_i32(
        &self,
        batch_rows: usize,
        cols: usize,
        out_width: usize,
        samples: &[&[i64]],
    ) -> anyhow::Result<Vec<Vec<i64>>> {
        let mut out = Vec::with_capacity(samples.len());
        let mut i = 0usize;
        while i < samples.len() {
            let take = (samples.len() - i).min(batch_rows);
            let mut flat = vec![0i64; batch_rows * cols];
            for (j, s) in samples[i..i + take].iter().enumerate() {
                if s.len() != cols {
                    anyhow::bail!(
                        "{}: sample {} has {} values, expected {cols}",
                        self.name,
                        i + j,
                        s.len()
                    );
                }
                flat[j * cols..(j + 1) * cols].copy_from_slice(s);
            }
            let outs = self.run(&[i32_matrix(batch_rows, cols, &flat)?])?;
            let vals = to_i32s(&outs[0])?;
            for j in 0..take {
                out.push(
                    vals[j * out_width..(j + 1) * out_width]
                        .iter()
                        .map(|&v| v as i64)
                        .collect(),
                );
            }
            i += take;
        }
        Ok(out)
    }
}

/// A PJRT CPU client plus a cache of compiled artifacts, keyed by name.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, Rc<Executable>>,
}

impl Engine {
    /// Create a CPU engine rooted at an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> anyhow::Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client init failed: {e:?}"))?;
        Ok(Engine { client, dir: artifact_dir.as_ref().to_path_buf(), cache: HashMap::new() })
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) `<dir>/<name>.hlo.txt`, compiling once.
    pub fn load(&mut self, name: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            anyhow::bail!(
                "artifact `{}` not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("{name}: HLO parse failed: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{name}: compile failed: {e:?}"))?;
        let exec = Rc::new(Executable { exe, name: name.to_string() });
        self.cache.insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Whether an artifact file exists (without compiling it).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }
}

// ---- literal helpers --------------------------------------------------------

/// Build an int32 [rows, cols] literal from raw fixed-point values.
pub fn i32_matrix(rows: usize, cols: usize, vals: &[i64]) -> anyhow::Result<xla::Literal> {
    assert_eq!(vals.len(), rows * cols);
    let v32: Vec<i32> = vals.iter().map(|&v| v as i32).collect();
    xla::Literal::vec1(&v32)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape failed: {e:?}"))
}

/// Build an f32 [rows, cols] literal.
pub fn f32_matrix(rows: usize, cols: usize, vals: &[f32]) -> anyhow::Result<xla::Literal> {
    assert_eq!(vals.len(), rows * cols);
    xla::Literal::vec1(vals)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape failed: {e:?}"))
}

/// Build an f32 vector literal.
pub fn f32_vec(vals: &[f32]) -> xla::Literal {
    xla::Literal::vec1(vals)
}

/// Build an f32 scalar literal.
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract `Vec<i32>` from a literal.
pub fn to_i32s(lit: &xla::Literal) -> anyhow::Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec<i32>: {e:?}"))
}

/// Extract `Vec<f32>` from a literal.
pub fn to_f32s(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))
}
