//! Switching-activity power model.
//!
//! The paper measures power physically: a 1 Ω sense resistor in the
//! iCE40's 1.2 V core rail, read by a Keithley DM7510, while an LFSR
//! drives the design. We substitute the standard CMOS dynamic-power
//! model evaluated on the *gate-level simulation* of the mapped netlist
//! under the same LFSR stimulus:
//!
//! ```text
//! P = P_static + C_eff · V² · f_clk · T
//! ```
//!
//! where `T` is the measured mean net toggles per clock cycle. `C_eff`
//! (an effective switched capacitance per toggle, folding in routing,
//! clock tree and glitching) and `P_static` are calibrated once against a
//! single Table-1 datum — the static pendulum at 6 MHz (1.1 mW) — and
//! then *predict* every other design and frequency (DESIGN.md §2).

use crate::fixedpoint::QFormat;
use crate::rtl::ir::PiModuleDesign;
use crate::stim::Lfsr32;
use crate::synth::{GateSim, Netlist};

/// Power model constants.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Core supply voltage (V). iCE40: 1.2 V.
    pub vdd: f64,
    /// Effective switched capacitance per net toggle (F).
    pub c_eff: f64,
    /// Static (leakage + bias) power (W).
    pub p_static: f64,
}

/// Calibrated iCE40 model (see module docs; calibration in
/// EXPERIMENTS.md §Table-1).
pub const ICE40: PowerModel = PowerModel {
    vdd: 1.2,
    // Calibrated so the pendulum design dissipates ≈1.1 mW at 6 MHz
    // (measured activity ≈103 toggles/cycle under LFSR stimulus).
    c_eff: 1.06e-12,
    p_static: 0.15e-3,
};

/// Measured switching activity of a design under LFSR stimulus.
#[derive(Clone, Copy, Debug)]
pub struct ActivityReport {
    /// Mean net toggles per clock cycle over the measurement window.
    pub toggles_per_cycle: f64,
    /// Cycles simulated.
    pub cycles: u64,
    /// Activations (samples processed).
    pub activations: u32,
}

/// Drive the mapped netlist with pseudorandom inputs for `activations`
/// back-to-back computations and measure toggle activity.
///
/// Inputs are drawn uniformly over a mid-scale operand range (the paper
/// fed "a pseudorandom signal input stream"); each activation runs to
/// `done` before the next starts, like the evaluation harness.
pub fn measure_activity(
    netlist: &Netlist,
    design: &PiModuleDesign,
    activations: u32,
    seed: u32,
) -> ActivityReport {
    let q: QFormat = design.q;
    let mut lfsr = Lfsr32::new(seed);
    let mut sim = GateSim::new(netlist);
    let mut cycles = 0u64;
    for _ in 0..activations {
        for p in &design.ports {
            let v = q.from_f64(lfsr.range(0.25, 12.0));
            sim.set_bus(&format!("in_{}", p.name), v);
        }
        sim.set_bus("start", 1);
        sim.step();
        cycles += 1;
        sim.set_bus("start", 0);
        let mut guard = 0u32;
        while !sim.get_bit("done") {
            sim.step();
            cycles += 1;
            guard += 1;
            assert!(guard < 5_000, "activation did not finish");
        }
    }
    ActivityReport {
        toggles_per_cycle: sim.total_toggles() as f64 / cycles.max(1) as f64,
        cycles,
        activations,
    }
}

/// Average power (watts) at clock `f_hz` for measured activity.
pub fn average_power(model: &PowerModel, activity: &ActivityReport, f_hz: f64) -> f64 {
    model.p_static + model.c_eff * model.vdd * model.vdd * f_hz * activity.toggles_per_cycle
}

/// Convenience: milliwatts.
pub fn average_power_mw(model: &PowerModel, activity: &ActivityReport, f_hz: f64) -> f64 {
    average_power(model, activity, f_hz) * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;
    use crate::synth::map_design;

    fn activity(id: &str, n: u32) -> (ActivityReport, PiModuleDesign) {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        (measure_activity(&mapped.netlist, &d, n, 0xACE1), d)
    }

    #[test]
    fn pendulum_power_near_paper_at_6mhz() {
        // Calibration target: paper says 1.1 mW at 6 MHz.
        let (act, _) = activity("pendulum", 6);
        let p = average_power_mw(&ICE40, &act, 6.0e6);
        assert!(
            (0.5..2.2).contains(&p),
            "pendulum @6MHz = {p:.2} mW (activity {:.1})",
            act.toggles_per_cycle
        );
    }

    #[test]
    fn power_scales_roughly_2x_with_frequency() {
        let (act, _) = activity("pendulum", 4);
        let p6 = average_power_mw(&ICE40, &act, 6.0e6);
        let p12 = average_power_mw(&ICE40, &act, 12.0e6);
        let ratio = p12 / p6;
        assert!((1.6..2.05).contains(&ratio), "ratio {ratio:.2}");
    }

    #[test]
    fn all_designs_under_10mw_at_12mhz() {
        // Paper: "the power dissipation is less than 6 mW" at 12 MHz.
        for e in corpus::corpus() {
            let (act, _) = activity(e.id, 3);
            let p = average_power_mw(&ICE40, &act, 12.0e6);
            assert!(p < 10.0, "{}: {p:.2} mW @12 MHz", e.id);
            assert!(p > 0.2, "{}: {p:.2} mW implausibly low", e.id);
        }
    }

    #[test]
    fn bigger_design_more_power() {
        let (small, _) = activity("pendulum", 3);
        let (big, _) = activity("fluid_pipe", 3);
        assert!(big.toggles_per_cycle > small.toggles_per_cycle);
    }

    #[test]
    fn activity_deterministic_for_seed() {
        let (a1, _) = activity("pendulum", 2);
        let (a2, _) = activity("pendulum", 2);
        assert_eq!(a1.toggles_per_cycle, a2.toggles_per_cycle);
        assert_eq!(a1.cycles, a2.cycles);
    }
}
