//! Switching-activity power model.
//!
//! The paper measures power physically: a 1 Ω sense resistor in the
//! iCE40's 1.2 V core rail, read by a Keithley DM7510, while an LFSR
//! drives the design. We substitute the standard CMOS dynamic-power
//! model evaluated on the *gate-level simulation* of the mapped netlist
//! under the same LFSR stimulus:
//!
//! ```text
//! P = P_static + C_eff · V² · f_clk · T
//! ```
//!
//! where `T` is the measured mean net toggles per clock cycle. `C_eff`
//! (an effective switched capacitance per toggle, folding in routing,
//! clock tree and glitching) and `P_static` are calibrated once against a
//! single Table-1 datum — the static pendulum at 6 MHz (1.1 mW) — and
//! then *predict* every other design and frequency (DESIGN.md §2).

use crate::fixedpoint::QFormat;
use crate::rtl::ir::PiModuleDesign;
use crate::stim::{Lfsr32, LfsrBank, LfsrBank64};
use crate::synth::{Drive, GateSim, LaneWidth, LaneWord, Netlist, WordSim, W256, W512};

/// Power model constants.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Core supply voltage (V). iCE40: 1.2 V.
    pub vdd: f64,
    /// Effective switched capacitance per net toggle (F).
    pub c_eff: f64,
    /// Static (leakage + bias) power (W).
    pub p_static: f64,
}

/// Calibrated iCE40 model (see module docs; calibration in
/// EXPERIMENTS.md §Table-1).
pub const ICE40: PowerModel = PowerModel {
    vdd: 1.2,
    // Calibrated so the pendulum design dissipates ≈1.1 mW at 6 MHz
    // (measured activity ≈103 toggles/cycle under LFSR stimulus).
    c_eff: 1.06e-12,
    p_static: 0.15e-3,
};

/// Measured switching activity of a design under LFSR stimulus.
#[derive(Clone, Copy, Debug)]
pub struct ActivityReport {
    /// Mean net toggles per clock cycle over the measurement window.
    pub toggles_per_cycle: f64,
    /// Cycles simulated.
    pub cycles: u64,
    /// Activations (samples processed).
    pub activations: u32,
}

/// Drive the mapped netlist with pseudorandom inputs for `activations`
/// back-to-back computations and measure toggle activity.
///
/// Inputs are drawn uniformly over a mid-scale operand range (the paper
/// fed "a pseudorandom signal input stream"); each activation runs to
/// `done` before the next starts, like the evaluation harness.
pub fn measure_activity(
    netlist: &Netlist,
    design: &PiModuleDesign,
    activations: u32,
    seed: u32,
) -> ActivityReport {
    let q: QFormat = design.q;
    let mut lfsr = Lfsr32::new(seed);
    let mut sim = GateSim::new(netlist);
    let mut cycles = 0u64;
    for _ in 0..activations {
        for p in &design.ports {
            let v = q.from_f64(lfsr.range(0.25, 12.0));
            sim.set_bus(&format!("in_{}", p.name), v);
        }
        sim.set_bus("start", 1);
        sim.step();
        cycles += 1;
        sim.set_bus("start", 0);
        let mut guard = 0u32;
        while !sim.get_bit("done") {
            sim.step();
            cycles += 1;
            guard += 1;
            assert!(guard < 5_000, "activation did not finish");
        }
    }
    ActivityReport {
        toggles_per_cycle: sim.total_toggles() as f64 / cycles.max(1) as f64,
        cycles,
        activations,
    }
}

/// Switching activity of `lanes.len()` independent stimulus streams,
/// measured in one word-parallel simulation pass
/// ([`measure_activity_batch`] / [`measure_activity_batch_wide`]).
#[derive(Clone, Debug)]
pub struct LaneActivityReport {
    /// Mean net toggles per clock cycle, one per lane (64 or 256
    /// entries, matching the engine's lane width).
    pub lanes: Vec<f64>,
    /// Cycles simulated (shared by all lanes — the corpus FSMs have
    /// data-independent latency, asserted during measurement).
    pub cycles: u64,
    /// Activations per lane.
    pub activations: u32,
}

impl LaneActivityReport {
    /// Mean toggles-per-cycle across lanes.
    pub fn mean(&self) -> f64 {
        self.lanes.iter().sum::<f64>() / self.lanes.len().max(1) as f64
    }

    /// Population standard deviation of toggles-per-cycle across lanes
    /// (the stimulus-induced spread of the activity estimate).
    pub fn spread(&self) -> f64 {
        let m = self.mean();
        (self.lanes.iter().map(|a| (a - m).powi(2)).sum::<f64>()
            / self.lanes.len().max(1) as f64)
            .sqrt()
    }

    /// View one lane as a scalar [`ActivityReport`].
    pub fn lane(&self, lane: usize) -> ActivityReport {
        ActivityReport {
            toggles_per_cycle: self.lanes[lane],
            cycles: self.cycles,
            activations: self.activations,
        }
    }
}

/// Width-shaped summary of a batched activity measurement: per-lane
/// toggles-per-cycle statistics at the lane width the measurement ran
/// at. This is the form the flow power stage persists — the summary is
/// what reports consume, and it keeps the full per-lane vector out of
/// the stored artifact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActivitySpread {
    /// Stimulus lanes measured (64, 256, or 512; 256 by default).
    pub lanes: u32,
    /// Mean toggles-per-cycle across lanes.
    pub mean_tpc: f64,
    /// Population standard deviation across lanes.
    pub std_tpc: f64,
    /// Extremes across lanes.
    pub min_tpc: f64,
    pub max_tpc: f64,
}

impl ActivitySpread {
    /// Summarize a batched measurement.
    pub fn of(report: &LaneActivityReport) -> ActivitySpread {
        let (mut min_tpc, mut max_tpc) = (f64::INFINITY, f64::NEG_INFINITY);
        for &a in &report.lanes {
            min_tpc = min_tpc.min(a);
            max_tpc = max_tpc.max(a);
        }
        if report.lanes.is_empty() {
            min_tpc = 0.0;
            max_tpc = 0.0;
        }
        ActivitySpread {
            lanes: report.lanes.len() as u32,
            mean_tpc: report.mean(),
            std_tpc: report.spread(),
            min_tpc,
            max_tpc,
        }
    }

    fn tpc_to_mw(model: &PowerModel, f_hz: f64, tpc: f64) -> f64 {
        (model.p_static + model.c_eff * model.vdd * model.vdd * f_hz * tpc) * 1e3
    }

    /// Minimum per-lane power (mW) under `model` at `f_hz`.
    pub fn min_mw(&self, model: &PowerModel, f_hz: f64) -> f64 {
        Self::tpc_to_mw(model, f_hz, self.min_tpc)
    }

    /// Mean per-lane power (mW).
    pub fn mean_mw(&self, model: &PowerModel, f_hz: f64) -> f64 {
        Self::tpc_to_mw(model, f_hz, self.mean_tpc)
    }

    /// Maximum per-lane power (mW).
    pub fn max_mw(&self, model: &PowerModel, f_hz: f64) -> f64 {
        Self::tpc_to_mw(model, f_hz, self.max_tpc)
    }

    /// Standard deviation of per-lane power (mW): power is affine in
    /// toggles-per-cycle, so the deviation scales by the slope.
    pub fn std_mw(&self, model: &PowerModel, f_hz: f64) -> f64 {
        model.c_eff * model.vdd * model.vdd * f_hz * 1e3 * self.std_tpc
    }
}

/// Draw one activation's operands (per-lane LFSR draws over the
/// mid-scale range, one draw per port bit in port order) and bind them
/// to the `in_*` buses, optionally under a bus-name prefix. This is the
/// single copy of the operand protocol: the solo activation loop below
/// and the fused multi-system driver in [`crate::shard`] both call it,
/// so a fused member sees exactly the operand stream its solo run sees.
pub(crate) fn apply_activation_inputs<W: LaneWord>(
    sim: &mut impl Drive<W>,
    design: &PiModuleDesign,
    bus_prefix: &str,
    values: &mut [i64],
    lfsrs: &mut [Lfsr32],
    q: QFormat,
) {
    for p in &design.ports {
        for (v, lfsr) in values.iter_mut().zip(lfsrs.iter_mut()) {
            *v = q.from_f64(lfsr.range(0.25, 12.0));
        }
        sim.set_bus_lanes(&format!("{bus_prefix}in_{}", p.name), values);
    }
}

/// The activation loop of the batched measurement: per-lane LFSR operand
/// draws, start pulse, run to `done`. Generic over the public
/// [`Drive`] surface, so the same loop serves the plain word simulator
/// and its intra-level parallel session. Returns cycles simulated.
fn drive_activations<W: LaneWord>(
    sim: &mut impl Drive<W>,
    design: &PiModuleDesign,
    activations: u32,
    lfsrs: &mut [Lfsr32],
    q: QFormat,
) -> u64 {
    let mut cycles = 0u64;
    let mut values = vec![0i64; W::LANES];
    for _ in 0..activations {
        apply_activation_inputs(sim, design, "", &mut values, lfsrs, q);
        sim.set_bus("start", 1);
        sim.step();
        cycles += 1;
        sim.set_bus("start", 0);
        let mut guard = 0u32;
        loop {
            let done = sim.get_bit_word("done");
            if done == W::ones() {
                break;
            }
            // The generated FSMs have data-independent latency, so all
            // lanes must finish on the same cycle; a mixed done word
            // would silently skew the shared cycle denominator.
            assert!(
                done.is_zero(),
                "lanes diverged on `done` (data-dependent latency?)"
            );
            sim.step();
            cycles += 1;
            guard += 1;
            assert!(guard < 5_000, "activation did not finish");
        }
    }
    cycles
}

/// Drive the mapped netlist with `W::LANES` independent pseudorandom
/// stimulus streams at once and measure per-lane toggle activity — the
/// word-parallel counterpart of [`measure_activity`], yielding `W::LANES`
/// power estimates (mean + spread) from one simulation pass.
///
/// Lane *l* sees exactly the operand stream `Lfsr32::new(seeds[l])`
/// would produce, so each lane is bit-identical to a scalar
/// `measure_activity` run with that seed, at either lane width.
///
/// `level_par_threshold` additionally fans each combinational level at
/// least that many packed LUTs wide out across worker threads
/// ([`WordSim::with_level_parallelism`]); results are bit-identical to
/// the sequential engine.
pub fn measure_activity_batch_wide<W: LaneWord>(
    netlist: &Netlist,
    design: &PiModuleDesign,
    activations: u32,
    seeds: &[u32],
    level_par_threshold: Option<usize>,
) -> LaneActivityReport {
    assert_eq!(seeds.len(), W::LANES, "expected one seed per lane");
    let q: QFormat = design.q;
    let mut lfsrs: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
    let mut sim = WordSim::<W>::new(netlist);
    if let Some(t) = level_par_threshold {
        sim = sim.with_level_parallelism(t);
    }
    // The session path carries per-step bookkeeping (toggle-word scratch,
    // deferred plane accounting); only take it when the plan actually
    // armed — on narrow netlists or single-core machines the plain
    // engine is strictly cheaper.
    let cycles = if sim.level_parallelism_active() {
        sim.parallel_session(|s| drive_activations(s, design, activations, &mut lfsrs, q))
    } else {
        drive_activations(&mut sim, design, activations, &mut lfsrs, q)
    };
    let lane_toggles = sim.lane_total_toggles();
    let lanes = lane_toggles
        .iter()
        .map(|&t| t as f64 / cycles.max(1) as f64)
        .collect();
    LaneActivityReport { lanes, cycles, activations }
}

/// The 64-lane batched measurement ([`measure_activity_batch_wide`] with
/// the default `u64` engine, no intra-level fan-out).
pub fn measure_activity_batch(
    netlist: &Netlist,
    design: &PiModuleDesign,
    activations: u32,
    seeds: &[u32],
) -> LaneActivityReport {
    measure_activity_batch_wide::<u64>(netlist, design, activations, seeds, None)
}

/// Convenience: measure 64 lanes with seeds derived from one master seed
/// (lane seeds are [`LfsrBank64::lane_seeds`], so scalar reference runs
/// can reproduce any lane).
pub fn measure_activity_spread(
    netlist: &Netlist,
    design: &PiModuleDesign,
    activations: u32,
    seed: u32,
) -> LaneActivityReport {
    measure_activity_batch(netlist, design, activations, &LfsrBank64::lane_seeds(seed))
}

/// [`measure_activity_spread`] at a runtime-selected lane width: one
/// pass yields 64 or 256 independent activity estimates. Seeds derive
/// from the master seed exactly as the fixed-width entry points do (the
/// 64-lane seed list is a prefix of the 256-lane one).
pub fn measure_activity_spread_width(
    netlist: &Netlist,
    design: &PiModuleDesign,
    activations: u32,
    seed: u32,
    width: LaneWidth,
    level_par_threshold: Option<usize>,
) -> LaneActivityReport {
    match width {
        LaneWidth::W64 => measure_activity_batch_wide::<u64>(
            netlist,
            design,
            activations,
            &LfsrBank::<u64>::lane_seeds(seed),
            level_par_threshold,
        ),
        LaneWidth::W256 => measure_activity_batch_wide::<W256>(
            netlist,
            design,
            activations,
            &LfsrBank::<W256>::lane_seeds(seed),
            level_par_threshold,
        ),
        LaneWidth::W512 => measure_activity_batch_wide::<W512>(
            netlist,
            design,
            activations,
            &LfsrBank::<W512>::lane_seeds(seed),
            level_par_threshold,
        ),
    }
}

/// Average power (watts) at clock `f_hz` for measured activity.
pub fn average_power(model: &PowerModel, activity: &ActivityReport, f_hz: f64) -> f64 {
    model.p_static + model.c_eff * model.vdd * model.vdd * f_hz * activity.toggles_per_cycle
}

/// Convenience: milliwatts.
pub fn average_power_mw(model: &PowerModel, activity: &ActivityReport, f_hz: f64) -> f64 {
    average_power(model, activity, f_hz) * 1e3
}

/// Per-lane power estimates (64 or 256, matching the measurement's lane
/// width) from one word-parallel activity measurement.
#[derive(Clone, Debug)]
pub struct PowerSpread {
    /// Per-lane power (milliwatts).
    pub lanes_mw: Vec<f64>,
    /// Mean across lanes (milliwatts).
    pub mean_mw: f64,
    /// Population standard deviation across lanes (milliwatts).
    pub std_mw: f64,
    /// Extremes across lanes (milliwatts).
    pub min_mw: f64,
    pub max_mw: f64,
}

/// Evaluate the power model on every lane of a batched activity
/// measurement at clock `f_hz`.
pub fn power_spread_mw(
    model: &PowerModel,
    activity: &LaneActivityReport,
    f_hz: f64,
) -> PowerSpread {
    let lanes_mw: Vec<f64> = (0..activity.lanes.len())
        .map(|lane| average_power_mw(model, &activity.lane(lane), f_hz))
        .collect();
    let n = lanes_mw.len().max(1) as f64;
    let mean_mw = lanes_mw.iter().sum::<f64>() / n;
    let var = lanes_mw.iter().map(|p| (p - mean_mw).powi(2)).sum::<f64>() / n;
    let (mut min_mw, mut max_mw) = (f64::INFINITY, f64::NEG_INFINITY);
    for &p in &lanes_mw {
        min_mw = min_mw.min(p);
        max_mw = max_mw.max(p);
    }
    PowerSpread { lanes_mw, mean_mw, std_mw: var.sqrt(), min_mw, max_mw }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Q16_15;
    use crate::newton::corpus;
    use crate::pisearch::analyze_optimized;
    use crate::rtl::ir;
    use crate::synth::map_design;

    fn activity(id: &str, n: u32) -> (ActivityReport, PiModuleDesign) {
        let e = corpus::by_id(id).unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        (measure_activity(&mapped.netlist, &d, n, 0xACE1), d)
    }

    #[test]
    fn pendulum_power_near_paper_at_6mhz() {
        // Calibration target: paper says 1.1 mW at 6 MHz.
        let (act, _) = activity("pendulum", 6);
        let p = average_power_mw(&ICE40, &act, 6.0e6);
        assert!(
            (0.5..2.2).contains(&p),
            "pendulum @6MHz = {p:.2} mW (activity {:.1})",
            act.toggles_per_cycle
        );
    }

    #[test]
    fn power_scales_roughly_2x_with_frequency() {
        let (act, _) = activity("pendulum", 4);
        let p6 = average_power_mw(&ICE40, &act, 6.0e6);
        let p12 = average_power_mw(&ICE40, &act, 12.0e6);
        let ratio = p12 / p6;
        assert!((1.6..2.05).contains(&ratio), "ratio {ratio:.2}");
    }

    #[test]
    fn all_designs_under_10mw_at_12mhz() {
        // Paper: "the power dissipation is less than 6 mW" at 12 MHz.
        for e in corpus::corpus() {
            let (act, _) = activity(e.id, 3);
            let p = average_power_mw(&ICE40, &act, 12.0e6);
            assert!(p < 10.0, "{}: {p:.2} mW @12 MHz", e.id);
            assert!(p > 0.2, "{}: {p:.2} mW implausibly low", e.id);
        }
    }

    #[test]
    fn bigger_design_more_power() {
        let (small, _) = activity("pendulum", 3);
        let (big, _) = activity("fluid_pipe", 3);
        assert!(big.toggles_per_cycle > small.toggles_per_cycle);
    }

    #[test]
    fn batch_lane_equals_scalar_run() {
        // Lane l of the batched measurement must reproduce a scalar
        // measure_activity run seeded with lane l's seed, exactly.
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        let seeds = crate::stim::LfsrBank64::lane_seeds(0x5EED);
        let batch = measure_activity_batch(&mapped.netlist, &d, 3, &seeds);
        for &lane in &[0usize, 1, 31, 63] {
            let scalar = measure_activity(&mapped.netlist, &d, 3, seeds[lane]);
            assert_eq!(batch.cycles, scalar.cycles, "lane {lane}");
            assert_eq!(
                batch.lanes[lane], scalar.toggles_per_cycle,
                "lane {lane} activity"
            );
        }
        assert!(batch.spread() >= 0.0);
        assert!(batch.mean() > 0.0);
    }

    #[test]
    fn wide_batch_matches_narrow_and_scalar() {
        // The 256-lane engine must agree lane-for-lane with the 64-lane
        // engine on the shared seed prefix, and with the scalar oracle
        // on upper lanes the narrow engine cannot reach.
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        let seeds256 = LfsrBank::<W256>::lane_seeds(0x5EED);
        let wide =
            measure_activity_batch_wide::<W256>(&mapped.netlist, &d, 2, &seeds256, None);
        assert_eq!(wide.lanes.len(), 256);
        let narrow = measure_activity_batch(&mapped.netlist, &d, 2, &seeds256[..64]);
        assert_eq!(wide.cycles, narrow.cycles);
        assert_eq!(&wide.lanes[..64], &narrow.lanes[..]);
        for &lane in &[77usize, 255] {
            let scalar = measure_activity(&mapped.netlist, &d, 2, seeds256[lane]);
            assert_eq!(wide.lanes[lane], scalar.toggles_per_cycle, "lane {lane}");
            assert_eq!(wide.cycles, scalar.cycles, "lane {lane}");
        }
    }

    #[test]
    fn intra_level_parallel_batch_is_bit_identical() {
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        let seeds = LfsrBank::<u64>::lane_seeds(0xCAFE);
        let seq =
            measure_activity_batch_wide::<u64>(&mapped.netlist, &d, 2, &seeds, None);
        // A small threshold forces the fan-out path on every wide level.
        let par =
            measure_activity_batch_wide::<u64>(&mapped.netlist, &d, 2, &seeds, Some(32));
        assert_eq!(seq.cycles, par.cycles);
        assert_eq!(seq.lanes, par.lanes);
    }

    #[test]
    fn spread_width_dispatch_is_prefix_consistent() {
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        let narrow = measure_activity_spread_width(
            &mapped.netlist, &d, 2, 0xACE1, LaneWidth::W64, None,
        );
        let wide = measure_activity_spread_width(
            &mapped.netlist, &d, 2, 0xACE1, LaneWidth::W256, None,
        );
        assert_eq!(narrow.lanes.len(), 64);
        assert_eq!(wide.lanes.len(), 256);
        assert_eq!(&wide.lanes[..64], &narrow.lanes[..]);
    }

    #[test]
    fn activity_spread_summary_matches_report() {
        let r = LaneActivityReport { lanes: vec![1.0, 3.0, 2.0], cycles: 10, activations: 1 };
        let s = ActivitySpread::of(&r);
        assert_eq!(s.lanes, 3);
        assert_eq!(s.min_tpc, 1.0);
        assert_eq!(s.max_tpc, 3.0);
        assert!((s.mean_tpc - 2.0).abs() < 1e-12);
        assert!((s.std_tpc - r.spread()).abs() < 1e-12);
        // The mW helpers are the power model applied to the tpc stats.
        let mean_act =
            ActivityReport { toggles_per_cycle: s.mean_tpc, cycles: 10, activations: 1 };
        let direct = average_power_mw(&ICE40, &mean_act, 6.0e6);
        assert!((s.mean_mw(&ICE40, 6.0e6) - direct).abs() < 1e-12);
        assert!(s.min_mw(&ICE40, 6.0e6) <= s.max_mw(&ICE40, 6.0e6));
        assert!(s.std_mw(&ICE40, 6.0e6) >= 0.0);
        // Empty report degrades to zeros, not infinities.
        let empty = ActivitySpread::of(&LaneActivityReport {
            lanes: Vec::new(),
            cycles: 0,
            activations: 0,
        });
        assert_eq!((empty.lanes, empty.min_tpc, empty.max_tpc), (0, 0.0, 0.0));
    }

    #[test]
    fn power_spread_brackets_mean() {
        let e = corpus::by_id("pendulum").unwrap();
        let m = corpus::load_entry(&e).unwrap();
        let a = analyze_optimized(&m, e.target).unwrap();
        let d = ir::build(&a, Q16_15);
        let mapped = map_design(&d);
        let act = measure_activity_spread(&mapped.netlist, &d, 3, 0xACE1);
        let spread = power_spread_mw(&ICE40, &act, 6.0e6);
        assert!(spread.min_mw <= spread.mean_mw && spread.mean_mw <= spread.max_mw);
        assert!(spread.std_mw >= 0.0);
        assert!((0.2..10.0).contains(&spread.mean_mw), "{}", spread.mean_mw);
        // Mean over lanes equals the model applied to the mean activity.
        let mean_act = ActivityReport {
            toggles_per_cycle: act.mean(),
            cycles: act.cycles,
            activations: act.activations,
        };
        let direct = average_power_mw(&ICE40, &mean_act, 6.0e6);
        assert!((spread.mean_mw - direct).abs() < 1e-9);
    }

    #[test]
    fn activity_deterministic_for_seed() {
        let (a1, _) = activity("pendulum", 2);
        let (a2, _) = activity("pendulum", 2);
        assert_eq!(a1.toggles_per_cycle, a2.toggles_per_cycle);
        assert_eq!(a1.cycles, a2.cycles);
    }
}
