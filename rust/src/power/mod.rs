//! Switching-activity power estimation (Table-1 "Avg. Power" columns).

pub mod model;

pub use model::{
    average_power, average_power_mw, measure_activity, ActivityReport, PowerModel, ICE40,
};
