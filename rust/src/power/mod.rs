//! Switching-activity power estimation (Table-1 "Avg. Power" columns).

pub mod model;

pub use model::{
    average_power, average_power_mw, measure_activity, measure_activity_batch,
    measure_activity_batch_wide, measure_activity_spread, measure_activity_spread_width,
    power_spread_mw, ActivityReport, ActivitySpread, LaneActivityReport, PowerModel,
    PowerSpread, ICE40,
};
