//! Switching-activity power estimation (Table-1 "Avg. Power" columns).

pub mod model;

pub use model::{
    average_power, average_power_mw, measure_activity, measure_activity_batch,
    measure_activity_spread, power_spread_mw, ActivityReport, LaneActivityReport,
    PowerModel, PowerSpread, ICE40,
};
