//! Differential validation of the bit-parallel 64-lane gate-level engine
//! ([`dimsynth::synth::WordSim`]) against the scalar reference oracle
//! ([`dimsynth::synth::GateSim`]).
//!
//! For every corpus design, one word-parallel run carrying 64 independent
//! LFSR stimulus streams (≥10k simulated cycles) is replayed lane by lane
//! through the scalar simulator, asserting bit-identical per-activation
//! outputs, cycle counts, and exact per-net toggle counts for each lane.

use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton::corpus;
use dimsynth::stim::{Lfsr32, LfsrBank64};
use dimsynth::synth::{GateSim, WordSim, LANES};

/// Minimum simulated cycles per design (per lane).
const MIN_CYCLES: u64 = 10_000;

#[test]
fn word_engine_matches_scalar_oracle_lane_by_lane() {
    for e in corpus::corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let design = flow.rtl().unwrap().clone();
        let mapped = flow.netlist().unwrap();
        let nl = &mapped.netlist;
        let q = design.q;
        let seeds = LfsrBank64::lane_seeds(0xD1FF);

        // One word-parallel run: 64 lanes of power-analysis stimulus,
        // recording every activation's outputs for lane-by-lane replay.
        let mut word = WordSim::new(nl).with_lane_net_toggles();
        let mut lfsrs: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
        let mut word_outputs: Vec<Vec<[i64; LANES]>> = Vec::new();
        while word.cycles() < MIN_CYCLES {
            for p in &design.ports {
                let mut vals = [0i64; LANES];
                for (v, l) in vals.iter_mut().zip(lfsrs.iter_mut()) {
                    *v = q.from_f64(l.range(0.25, 12.0));
                }
                word.set_bus_lanes(&format!("in_{}", p.name), &vals);
            }
            word.set_bus("start", 1);
            word.step();
            word.set_bus("start", 0);
            let mut guard = 0u32;
            loop {
                let done = word.get_bit_word("done");
                if done == u64::MAX {
                    break;
                }
                assert_eq!(done, 0, "{}: lanes diverged on `done`", e.id);
                word.step();
                guard += 1;
                assert!(guard < 5_000, "{}: activation did not finish", e.id);
            }
            let outs: Vec<[i64; LANES]> = (0..design.num_outputs())
                .map(|u| word.get_output_lanes(&format!("pi_{u}")))
                .collect();
            word_outputs.push(outs);
        }
        let activations = word_outputs.len();

        // 64 scalar oracle runs, one per lane, with the identical
        // per-lane stimulus stream.
        for lane in 0..LANES {
            let mut scalar = GateSim::new(nl);
            let mut lfsr = Lfsr32::new(seeds[lane]);
            for (act, outs) in word_outputs.iter().enumerate() {
                for p in &design.ports {
                    let v = q.from_f64(lfsr.range(0.25, 12.0));
                    scalar.set_bus(&format!("in_{}", p.name), v);
                }
                scalar.set_bus("start", 1);
                scalar.step();
                scalar.set_bus("start", 0);
                while !scalar.get_bit("done") {
                    scalar.step();
                }
                for (u, lanes) in outs.iter().enumerate() {
                    assert_eq!(
                        lanes[lane],
                        scalar.get_output(&format!("pi_{u}")),
                        "{}: lane {lane} activation {act} output pi_{u}",
                        e.id
                    );
                }
            }
            assert_eq!(
                scalar.cycles(),
                word.cycles(),
                "{}: lane {lane} cycle count",
                e.id
            );
            assert_eq!(
                word.lane_net_toggles(lane).as_slice(),
                scalar.toggles(),
                "{}: lane {lane} per-net toggle counts",
                e.id
            );
        }
        assert!(
            word.cycles() >= MIN_CYCLES,
            "{}: only {} cycles simulated",
            e.id,
            word.cycles()
        );
        eprintln!(
            "{}: {} activations, {} cycles x {LANES} lanes, {} nets: lane-exact",
            e.id,
            activations,
            word.cycles(),
            nl.len()
        );
    }
}

#[test]
fn word_engine_aggregates_match_scalar_sums() {
    // Cross-check the word-parallel aggregate counters (popcount per-net
    // totals and the bit-plane per-lane totals) against scalar sums on
    // one design — these are the counters the power model consumes.
    let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
    let design = flow.rtl().unwrap().clone();
    let mapped = flow.netlist().unwrap();
    let seeds = LfsrBank64::lane_seeds(0xA66A);

    let mut word = WordSim::new(&mapped.netlist);
    let mut lfsrs: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
    for _ in 0..3 {
        for p in &design.ports {
            let mut vals = [0i64; LANES];
            for (v, l) in vals.iter_mut().zip(lfsrs.iter_mut()) {
                *v = q_from(l);
            }
            word.set_bus_lanes(&format!("in_{}", p.name), &vals);
        }
        word.set_bus("start", 1);
        word.step();
        word.set_bus("start", 0);
        while word.get_bit_word("done") != u64::MAX {
            word.step();
        }
    }

    let mut per_net_sum = vec![0u64; mapped.netlist.len()];
    let mut lane_totals = [0u64; LANES];
    for lane in 0..LANES {
        let mut scalar = GateSim::new(&mapped.netlist);
        let mut lfsr = Lfsr32::new(seeds[lane]);
        for _ in 0..3 {
            for p in &design.ports {
                scalar.set_bus(&format!("in_{}", p.name), q_from(&mut lfsr));
            }
            scalar.set_bus("start", 1);
            scalar.step();
            scalar.set_bus("start", 0);
            while !scalar.get_bit("done") {
                scalar.step();
            }
        }
        for (net, &t) in scalar.toggles().iter().enumerate() {
            per_net_sum[net] += t;
        }
        lane_totals[lane] = scalar.total_toggles();
    }
    assert_eq!(word.toggles(), per_net_sum.as_slice());
    assert_eq!(word.lane_total_toggles(), lane_totals);
}

fn q_from(lfsr: &mut Lfsr32) -> i64 {
    Q16_15.from_f64(lfsr.range(0.25, 12.0))
}
