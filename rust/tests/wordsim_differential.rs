//! Differential validation of the bit-parallel gate-level engine
//! ([`dimsynth::synth::WordSim`]) against the scalar reference oracle
//! ([`dimsynth::synth::GateSim`]), at **every lane width** (`u64` = 64
//! lanes, [`W256`] = 256 lanes, [`W512`] = 512 lanes).
//!
//! For every corpus design, one word-parallel run carrying independent
//! LFSR stimulus streams (≥10k simulated cycles) is checked against the
//! scalar simulator, asserting bit-identical per-activation outputs,
//! cycle counts, and exact per-net toggle counts per lane:
//!
//! * at 64 lanes, **every** lane is replayed through the scalar oracle;
//! * at 256 lanes, the first 64 lanes are proven identical to the
//!   64-lane engine's (same seed prefix — word-vs-word, all lanes), and
//!   a spread of upper lanes (65..255) is replayed through the scalar
//!   oracle directly, anchoring the lanes the narrow engine cannot
//!   reach. A full 256-lane scalar replay of the whole corpus would
//!   quadruple the suite's dominant cost for no additional coverage of
//!   the width-specific code paths.
//!
//! The plane-overflow flush path (the `u32::MAX` adds guard) and the
//! intra-level parallel mode are exercised here too: both must be
//! invisible in every counter.

use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton::corpus;
use dimsynth::power;
use dimsynth::rtl::PiModuleDesign;
use dimsynth::stim::{Lfsr32, LfsrBank};
use dimsynth::synth::{GateSim, LaneWord, Netlist, WordSim, W256, W512};

/// Minimum simulated cycles per design (per lane).
const MIN_CYCLES: u64 = 10_000;

/// Drive one word-parallel power-stimulus run to at least `min_cycles`,
/// recording every activation's outputs for all lanes. Lane *l*'s
/// operand stream is `Lfsr32::new(seeds[l])`, identical to a scalar
/// run. `flush_adds` optionally lowers the bit-plane flush threshold
/// (the overflow-guard differential reuses this same drive loop so the
/// stimulus protocol lives in exactly one place).
fn word_run<'n, W: LaneWord>(
    nl: &'n Netlist,
    design: &PiModuleDesign,
    seeds: &[u32],
    min_cycles: u64,
    flush_adds: Option<u64>,
) -> (WordSim<'n, W>, Vec<Vec<Vec<i64>>>) {
    let q = design.q;
    let mut word = WordSim::<W>::new(nl).with_lane_net_toggles();
    if let Some(adds) = flush_adds {
        word = word.with_plane_flush_threshold(adds);
    }
    let mut lfsrs: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
    let mut outputs: Vec<Vec<Vec<i64>>> = Vec::new();
    while word.cycles() < min_cycles {
        for p in &design.ports {
            let mut vals = vec![0i64; W::LANES];
            for (v, l) in vals.iter_mut().zip(lfsrs.iter_mut()) {
                *v = q.from_f64(l.range(0.25, 12.0));
            }
            word.set_bus_lanes(&format!("in_{}", p.name), &vals);
        }
        word.set_bus("start", 1);
        word.step();
        word.set_bus("start", 0);
        let mut guard = 0u32;
        loop {
            let done = word.get_bit_word("done");
            if done == W::ones() {
                break;
            }
            assert!(done.is_zero(), "lanes diverged on `done`");
            word.step();
            guard += 1;
            assert!(guard < 5_000, "activation did not finish");
        }
        let outs: Vec<Vec<i64>> = (0..design.num_outputs())
            .map(|u| word.get_output_lanes(&format!("pi_{u}")))
            .collect();
        outputs.push(outs);
    }
    (word, outputs)
}

/// Replay one lane's stimulus through the scalar oracle and assert
/// bit-identical outputs, cycle count, and exact per-net toggles.
fn assert_lane_matches_scalar<W: LaneWord>(
    id: &str,
    nl: &Netlist,
    design: &PiModuleDesign,
    word: &WordSim<'_, W>,
    word_outputs: &[Vec<Vec<i64>>],
    seed: u32,
    lane: usize,
) {
    let q = design.q;
    let mut scalar = GateSim::new(nl);
    let mut lfsr = Lfsr32::new(seed);
    for (act, outs) in word_outputs.iter().enumerate() {
        for p in &design.ports {
            let v = q.from_f64(lfsr.range(0.25, 12.0));
            scalar.set_bus(&format!("in_{}", p.name), v);
        }
        scalar.set_bus("start", 1);
        scalar.step();
        scalar.set_bus("start", 0);
        while !scalar.get_bit("done") {
            scalar.step();
        }
        for (u, lanes) in outs.iter().enumerate() {
            assert_eq!(
                lanes[lane],
                scalar.get_output(&format!("pi_{u}")),
                "{id}: lane {lane} activation {act} output pi_{u}"
            );
        }
    }
    assert_eq!(scalar.cycles(), word.cycles(), "{id}: lane {lane} cycle count");
    assert_eq!(
        word.lane_net_toggles(lane).as_slice(),
        scalar.toggles(),
        "{id}: lane {lane} per-net toggle counts"
    );
}

#[test]
fn word64_engine_matches_scalar_oracle_lane_by_lane() {
    for e in corpus::corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let design = flow.rtl().unwrap().clone();
        let mapped = flow.netlist().unwrap();
        let nl = &mapped.netlist;
        let seeds = LfsrBank::<u64>::lane_seeds(0xD1FF);

        let (word, word_outputs) = word_run::<u64>(nl, &design, &seeds, MIN_CYCLES, None);
        let activations = word_outputs.len();

        // Every lane replays exactly through the scalar oracle.
        for lane in 0..64 {
            assert_lane_matches_scalar(
                e.id, nl, &design, &word, &word_outputs, seeds[lane], lane,
            );
        }
        assert!(
            word.cycles() >= MIN_CYCLES,
            "{}: only {} cycles simulated",
            e.id,
            word.cycles()
        );
        eprintln!(
            "{}: {} activations, {} cycles x 64 lanes, {} nets: lane-exact",
            e.id,
            activations,
            word.cycles(),
            nl.len()
        );
    }
}

#[test]
fn word256_engine_matches_narrow_engine_and_scalar_oracle() {
    // Upper lanes sampled for direct scalar replay: word boundaries and
    // interior points of each of the three u64 elements the 64-lane
    // engine never exercises.
    const UPPER_LANES: [usize; 6] = [64, 65, 127, 128, 191, 255];
    for e in corpus::corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let design = flow.rtl().unwrap().clone();
        let mapped = flow.netlist().unwrap();
        let nl = &mapped.netlist;
        let seeds = LfsrBank::<W256>::lane_seeds(0xD1FF);

        let (mut wide, wide_outputs) = word_run::<W256>(nl, &design, &seeds, MIN_CYCLES, None);
        let (mut narrow, narrow_outputs) =
            word_run::<u64>(nl, &design, &seeds[..64], MIN_CYCLES, None);

        // The wide engine's first 64 lanes are the narrow engine's run
        // (same seed prefix): outputs, cycles, per-lane totals, and
        // exact per-net counters must all agree, for every lane and
        // every activation.
        assert_eq!(wide.cycles(), narrow.cycles(), "{}: cycle count", e.id);
        assert_eq!(wide_outputs.len(), narrow_outputs.len(), "{}: activations", e.id);
        for (act, (w_outs, n_outs)) in
            wide_outputs.iter().zip(&narrow_outputs).enumerate()
        {
            for (u, (w_lanes, n_lanes)) in w_outs.iter().zip(n_outs).enumerate() {
                assert_eq!(
                    &w_lanes[..64],
                    &n_lanes[..],
                    "{}: activation {act} output pi_{u} lanes 0..64",
                    e.id
                );
            }
        }
        for lane in 0..64 {
            assert_eq!(
                wide.lane_net_toggles(lane),
                narrow.lane_net_toggles(lane),
                "{}: lane {lane} exact toggles",
                e.id
            );
        }
        let wide_totals = wide.lane_total_toggles();
        let narrow_totals = narrow.lane_total_toggles();
        assert_eq!(&wide_totals[..64], &narrow_totals[..], "{}: per-lane totals", e.id);

        // Upper lanes anchor directly to the scalar oracle.
        for &lane in &UPPER_LANES {
            assert_lane_matches_scalar(
                e.id, nl, &design, &wide, &wide_outputs, seeds[lane], lane,
            );
        }

        // Aggregate counters are consistent with the exact per-lane ones.
        let total: u64 = wide.lane_total_toggles().iter().sum();
        assert_eq!(total, wide.total_toggles(), "{}: total toggles", e.id);
        assert!(wide.cycles() >= MIN_CYCLES, "{}: too few cycles", e.id);
        eprintln!(
            "{}: {} cycles x 256 lanes, {} nets: prefix-exact vs 64-lane, oracle-exact on {:?}",
            e.id,
            wide.cycles(),
            nl.len(),
            UPPER_LANES
        );
    }
}

#[test]
fn word512_engine_matches_mid_engine_and_scalar_oracle() {
    // The widest lane word anchors both ways: its first 256 lanes must
    // be the 256-lane engine's run verbatim (same seed prefix — that
    // engine is itself corpus-proven against the scalar oracle above),
    // and sampled upper lanes (element boundaries and interiors of the
    // four u64 elements no narrower engine reaches) replay directly
    // through the scalar oracle. One design keeps the 512-wide scalar
    // replays from dominating the suite; the width-specific code path
    // is per-word, not per-design.
    const UPPER_LANES: [usize; 5] = [256, 257, 383, 448, 511];
    let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
    let design = flow.rtl().unwrap().clone();
    let mapped = flow.netlist().unwrap();
    let nl = &mapped.netlist;
    let seeds = LfsrBank::<W512>::lane_seeds(0xD1FF);

    let (mut wide, wide_outputs) = word_run::<W512>(nl, &design, &seeds, MIN_CYCLES, None);
    let (mut mid, mid_outputs) = word_run::<W256>(nl, &design, &seeds[..256], MIN_CYCLES, None);

    assert_eq!(wide.cycles(), mid.cycles(), "cycle count");
    assert_eq!(wide_outputs.len(), mid_outputs.len(), "activations");
    for (act, (w_outs, m_outs)) in wide_outputs.iter().zip(&mid_outputs).enumerate() {
        for (u, (w_lanes, m_lanes)) in w_outs.iter().zip(m_outs).enumerate() {
            assert_eq!(
                &w_lanes[..256],
                &m_lanes[..],
                "activation {act} output pi_{u} lanes 0..256"
            );
        }
    }
    for lane in 0..256 {
        assert_eq!(
            wide.lane_net_toggles(lane),
            mid.lane_net_toggles(lane),
            "lane {lane} exact toggles"
        );
    }
    let wide_totals = wide.lane_total_toggles();
    let mid_totals = mid.lane_total_toggles();
    assert_eq!(&wide_totals[..256], &mid_totals[..], "per-lane totals");

    for &lane in &UPPER_LANES {
        assert_lane_matches_scalar(
            "pendulum", nl, &design, &wide, &wide_outputs, seeds[lane], lane,
        );
    }
    let total: u64 = wide.lane_total_toggles().iter().sum();
    assert_eq!(total, wide.total_toggles(), "total toggles");
}

fn aggregates_match_scalar_sums_impl<W: LaneWord>() {
    // Cross-check the word-parallel aggregate counters (popcount per-net
    // totals and the bit-plane per-lane totals) against scalar sums on
    // one design — these are the counters the power model consumes.
    let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
    let design = flow.rtl().unwrap().clone();
    let mapped = flow.netlist().unwrap();
    let seeds = LfsrBank::<W>::lane_seeds(0xA66A);

    let mut word = WordSim::<W>::new(&mapped.netlist);
    let mut lfsrs: Vec<Lfsr32> = seeds.iter().map(|&s| Lfsr32::new(s)).collect();
    for _ in 0..3 {
        for p in &design.ports {
            let mut vals = vec![0i64; W::LANES];
            for (v, l) in vals.iter_mut().zip(lfsrs.iter_mut()) {
                *v = q_from(l);
            }
            word.set_bus_lanes(&format!("in_{}", p.name), &vals);
        }
        word.set_bus("start", 1);
        word.step();
        word.set_bus("start", 0);
        while word.get_bit_word("done") != W::ones() {
            word.step();
        }
    }

    let mut per_net_sum = vec![0u64; mapped.netlist.len()];
    let mut lane_totals = vec![0u64; W::LANES];
    for lane in 0..W::LANES {
        let mut scalar = GateSim::new(&mapped.netlist);
        let mut lfsr = Lfsr32::new(seeds[lane]);
        for _ in 0..3 {
            for p in &design.ports {
                scalar.set_bus(&format!("in_{}", p.name), q_from(&mut lfsr));
            }
            scalar.set_bus("start", 1);
            scalar.step();
            scalar.set_bus("start", 0);
            while !scalar.get_bit("done") {
                scalar.step();
            }
        }
        for (net, &t) in scalar.toggles().iter().enumerate() {
            per_net_sum[net] += t;
        }
        lane_totals[lane] = scalar.total_toggles();
    }
    assert_eq!(word.toggles(), per_net_sum.as_slice());
    assert_eq!(word.lane_total_toggles(), lane_totals);
}

#[test]
fn word_engine_aggregates_match_scalar_sums() {
    aggregates_match_scalar_sums_impl::<u64>();
    aggregates_match_scalar_sums_impl::<W256>();
    aggregates_match_scalar_sums_impl::<W512>();
}

fn overflow_flush_impl<W: LaneWord>() {
    // The production flush fires once the carry-save accumulator nears
    // u32::MAX adds — unreachable in a test, so the same guard is driven
    // with a threshold barely above one step's worst case. Flushing on
    // virtually every step must be invisible in every counter, at both
    // lane widths.
    let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
    let design = flow.rtl().unwrap().clone();
    let mapped = flow.netlist().unwrap();
    let nl = &mapped.netlist;
    let seeds = LfsrBank::<W>::lane_seeds(0xF1A5);

    // A few hundred cycles ≈ several activations; the tiny threshold
    // makes virtually every step take the overflow-flush path.
    let (mut flushing, _) =
        word_run::<W>(nl, &design, &seeds, 400, Some(2 * nl.len() as u64 + 1));
    let (mut reference, _) = word_run::<W>(nl, &design, &seeds, 400, None);
    assert_eq!(flushing.cycles(), reference.cycles());
    assert_eq!(flushing.toggles(), reference.toggles());
    assert_eq!(flushing.lane_total_toggles(), reference.lane_total_toggles());
    for lane in [0usize, 1, W::LANES / 2, W::LANES - 1] {
        assert_eq!(
            flushing.lane_net_toggles(lane),
            reference.lane_net_toggles(lane),
            "lane {lane}"
        );
    }
}

#[test]
fn plane_overflow_flush_is_invisible_in_all_counters() {
    overflow_flush_impl::<u64>();
    overflow_flush_impl::<W256>();
    overflow_flush_impl::<W512>();
}

#[test]
fn intra_level_parallel_differential_on_largest_corpus_netlist() {
    // Parallel == sequential, bit for bit, on the biggest netlist (the
    // one the intra-level fan-out targets), at both lane widths.
    let mut biggest: Option<(String, usize)> = None;
    for e in corpus::corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let n = flow.netlist().unwrap().netlist.len();
        if biggest.as_ref().map(|&(_, m)| n > m).unwrap_or(true) {
            biggest = Some((e.id.to_string(), n));
        }
    }
    let (id, _) = biggest.expect("corpus is non-empty");
    let mut flow = Flow::for_system(&id, FlowConfig::default()).unwrap();
    let design = flow.rtl().unwrap().clone();
    let mapped = flow.netlist().unwrap();

    let seeds = LfsrBank::<u64>::lane_seeds(0xBEEF);
    let seq = power::measure_activity_batch_wide::<u64>(
        &mapped.netlist, &design, 2, &seeds, None,
    );
    // Tiny threshold: force the fan-out path on every level wide enough
    // to split at all.
    let par = power::measure_activity_batch_wide::<u64>(
        &mapped.netlist, &design, 2, &seeds, Some(16),
    );
    assert_eq!(seq.cycles, par.cycles, "{id}: cycles");
    assert_eq!(seq.lanes, par.lanes, "{id}: per-lane activity");

    let seeds256 = LfsrBank::<W256>::lane_seeds(0xBEEF);
    let seq256 = power::measure_activity_batch_wide::<W256>(
        &mapped.netlist, &design, 2, &seeds256, None,
    );
    let par256 = power::measure_activity_batch_wide::<W256>(
        &mapped.netlist, &design, 2, &seeds256, Some(16),
    );
    assert_eq!(seq256.cycles, par256.cycles, "{id}: cycles (256)");
    assert_eq!(seq256.lanes, par256.lanes, "{id}: per-lane activity (256)");
}

fn q_from(lfsr: &mut Lfsr32) -> i64 {
    Q16_15.from_f64(lfsr.range(0.25, 12.0))
}
