//! Property-style tests sweeping randomized inputs across layer
//! boundaries with the repo LFSR (no external proptest dependency):
//! the same Π semantics must hold at every level of the stack, and the
//! Π-search invariants must hold for randomized synthetic systems.

use dimsynth::fixedpoint::{self, QFormat, Q16_15};
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton::corpus;
use dimsynth::pisearch::{self, RMatrix};
use dimsynth::rational::Rational;
use dimsynth::rtl;
use dimsynth::stim::Lfsr32;
use dimsynth::synth;
use dimsynth::units::{BaseDim, Dimension};

/// Randomized dimensional systems: the nullspace property Π-search relies
/// on must hold for arbitrary dimension assignments, not just the corpus.
#[test]
fn prop_nullspace_vectors_are_dimensionless() {
    let mut rng = Lfsr32::new(0xA11CE);
    for trial in 0..200 {
        let k = 3 + rng.below(5); // 3..=7 symbols
        let dims: Vec<Dimension> = (0..k)
            .map(|_| {
                let t = rng.below(5) as i64 - 2;
                let l = rng.below(5) as i64 - 2;
                let m = rng.below(3) as i64 - 1;
                Dimension::base(BaseDim::Time).powi(t)
                    * Dimension::base(BaseDim::Length).powi(l)
                    * Dimension::base(BaseDim::Mass).powi(m)
            })
            .collect();
        let mat = RMatrix::dimensional(&dims);
        let basis = mat.nullspace();
        assert_eq!(basis.len(), k - mat.rank(), "trial {trial}: nullity mismatch");
        for v in &basis {
            // Exact check: D·v = 0.
            let out = mat.mul_vec(v);
            assert!(out.iter().all(Rational::is_zero), "trial {trial}");
            // Physical check: ∏ dims^v dimensionless (integer-scaled).
            let ints = pisearch::integerize(v);
            let mut d = Dimension::NONE;
            for (i, &e) in ints.iter().enumerate() {
                d = d * dims[i].powi(e);
            }
            assert!(d.is_dimensionless(), "trial {trial}: {d}");
        }
    }
}

/// Fixed-point algebraic properties that the hardware relies on.
#[test]
fn prop_fixedpoint_algebra() {
    let mut rng = Lfsr32::new(0xF1C5);
    let q = Q16_15;
    for _ in 0..5_000 {
        let a = q.from_f64(rng.range(-100.0, 100.0));
        let b = q.from_f64(rng.range(-100.0, 100.0));
        // Commutativity of multiply.
        assert_eq!(fixedpoint::mul(q, a, b), fixedpoint::mul(q, b, a));
        // Identity.
        assert_eq!(fixedpoint::mul(q, a, q.one()), a);
        assert_eq!(fixedpoint::div(q, a, q.one()), a);
        // Sign symmetry of divide (sign-magnitude semantics).
        if b != 0 {
            let d = fixedpoint::div(q, a, b);
            assert_eq!(fixedpoint::div(q, -a, b), q.saturate(-(d as i128)));
        }
        // Multiply result bounded.
        let m = fixedpoint::mul(q, a, b);
        assert!(m >= q.min_raw() && m <= q.max_raw());
    }
}

/// x/y*y stays within truncation error of x.
#[test]
fn prop_div_mul_roundtrip() {
    let mut rng = Lfsr32::new(0x0DD);
    let q = Q16_15;
    for _ in 0..2_000 {
        let x = q.from_f64(rng.range(0.1, 500.0));
        let y = q.from_f64(rng.range(0.1, 500.0));
        let d = fixedpoint::div(q, x, y);
        if d == q.max_raw() || d == q.min_raw() || d == 0 {
            continue;
        }
        let back = fixedpoint::mul(q, d, y);
        // Truncation in the divide loses < 1 quotient lsb → after the
        // multiply the error is bounded by |y| lsb-equivalents + rounding.
        let bound = (y.abs() >> q.frac_bits) + 2;
        assert!(
            (back - x).abs() <= bound,
            "x={x} y={y} d={d} back={back} bound={bound}"
        );
    }
}

/// The full stack agrees on random vectors for every corpus design:
/// software model == cycle-accurate RTL sim == packed gate netlist.
#[test]
fn prop_three_level_equivalence_randomized() {
    let mut rng = Lfsr32::new(0x3117);
    for e in corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let d = flow.rtl().unwrap().clone();
        let mapped = flow.netlist().unwrap();
        for trial in 0..4 {
            let inputs: Vec<i64> = (0..d.num_inputs())
                .map(|_| {
                    // Mix magnitudes, signs, and occasional zeros.
                    if rng.below(16) == 0 {
                        0
                    } else {
                        Q16_15.from_f64(rng.range(-64.0, 64.0))
                    }
                })
                .collect();
            let sw = rtl::sim::reference_outputs(&d, &inputs);
            let hw = rtl::run_once(&d, &inputs);
            assert_eq!(sw, hw.outputs, "{}: sw vs rtl, trial {trial}", e.id);

            let mut gs = synth::GateSim::new(&mapped.netlist);
            for (p, v) in d.ports.iter().zip(&inputs) {
                gs.set_bus(&format!("in_{}", p.name), *v);
            }
            gs.set_bus("start", 1);
            gs.step();
            gs.set_bus("start", 0);
            let mut n = 0u32;
            while !gs.get_bit("done") {
                gs.step();
                n += 1;
                assert!(n < 3000, "{}: gate sim stuck", e.id);
            }
            for (u, &expect) in sw.iter().enumerate() {
                assert_eq!(
                    gs.get_output(&format!("pi_{u}")),
                    expect,
                    "{}: gates vs sw, unit {u}, trial {trial}",
                    e.id
                );
            }
            assert_eq!(u64::from(n), hw.cycles, "{}: cycle mismatch", e.id);
        }
    }
}

/// Monomial evaluation respects exponent additivity when exact:
/// eval(e1 + e2) over multiplication-only schedules equals
/// mul(eval(e1), eval(e2)) up to one rounding step per op.
#[test]
fn prop_monomial_compositionality_bound() {
    let mut rng = Lfsr32::new(0xC0);
    let q = Q16_15;
    for _ in 0..500 {
        let vals: Vec<i64> = (0..3).map(|_| q.from_f64(rng.range(0.5, 4.0))).collect();
        let e1 = [1i64, 1, 0];
        let e2 = [0i64, 0, 1];
        let sum = [1i64, 1, 1];
        let a = fixedpoint::eval_monomial(q, &vals, &e1);
        let b = fixedpoint::eval_monomial(q, &vals, &e2);
        let combined = fixedpoint::eval_monomial(q, &vals, &sum);
        let product = fixedpoint::mul(q, a, b);
        // Both compute v0·v1·v2 with different association; rounding can
        // differ by a couple of lsb.
        assert!(
            (combined - product).abs() <= 2,
            "vals {vals:?}: {combined} vs {product}"
        );
    }
}

/// Parametric-format equivalence: the Rust model and the RTL sim agree
/// for random formats, not just Q16.15.
#[test]
fn prop_random_formats_agree() {
    let mut rng = Lfsr32::new(0xF0F0);
    // One session across all random formats: parse/Π-search stay cached,
    // `set_qformat` rebuilds only the RTL stage.
    let mut flow = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
    for _ in 0..6 {
        let frac = 5 + rng.below(18) as u32; // 5..=22
        let int = 6 + rng.below(10) as u32; // 6..=15
        let q = QFormat::new(int, frac);
        flow.set_qformat(q);
        let d = flow.rtl().unwrap();
        for _ in 0..5 {
            let inputs: Vec<i64> =
                (0..d.num_inputs()).map(|_| q.from_f64(rng.range(0.3, 5.0))).collect();
            assert_eq!(
                rtl::run_once(d, &inputs).outputs,
                rtl::sim::reference_outputs(d, &inputs),
                "format {q}"
            );
        }
    }
    assert_eq!(flow.counts().pis, 1, "Π-search must not recompute per format");
}
