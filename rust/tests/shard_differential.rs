//! Corpus-wide differential: the fused + sharded evaluation path
//! ([`dimsynth::shard`]) against the per-system word-parallel reference
//! ([`power::measure_activity_batch_wide`]), across shard counts and
//! lane widths.
//!
//! All corpus systems are fused into one module; each member runs its
//! own activation schedule (counts deliberately skewed so members
//! finish at different global steps) with its own per-lane LFSR seeds.
//! For K ∈ {1, 2, 4, 8} and lanes ∈ {64, 256, 512} every member's
//! report must be **bit-identical** to its solo run: cycle count,
//! per-lane mean toggle rates, and the power figures derived from
//! them. Equality is exact (`==` on the f64s) — the fused driver is a
//! linearization of the solo activation loop, not an approximation of
//! it. Each run also checks the dirty-word exchange counters obey
//! their accounting identity: one publication opportunity per owned
//! cut word per cycle, never more publications than cut words × sync
//! phases.

use dimsynth::flow::{ensure_fused, Flow, FlowConfig};
use dimsynth::newton::corpus;
use dimsynth::power::{self, LaneActivityReport, ICE40};
use dimsynth::rtl::PiModuleDesign;
use dimsynth::shard::{measure_fused_activity, MemberStim, ShardPlan, ShardSim};
use dimsynth::stim::LfsrBank;
use dimsynth::synth::{LaneWord, Netlist, W256, W512};

/// Skewed activation schedule: members finish at different global
/// steps, exercising the mid-run member-snapshot path.
fn activations_of(member: usize) -> u32 {
    1 + (member % 3) as u32
}

/// Per-member seed bank: every member drives distinct lane streams, so
/// a cross-member scatter bug cannot cancel out.
fn seeds_of<W: LaneWord>(member: usize) -> Vec<u32> {
    LfsrBank::<W>::lane_seeds(0xC0FE ^ (member as u32).wrapping_mul(0x9E37_79B9))
}

fn fused_sharded_matches_solo_impl<W: LaneWord>(shard_counts: &[usize]) {
    // Compile the whole corpus once; both sides reuse the same mapped
    // netlists and designs.
    let mut designs: Vec<PiModuleDesign> = Vec::new();
    let mut mapped = Vec::new();
    let mut ids: Vec<&str> = Vec::new();
    for e in corpus::corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        designs.push(flow.rtl().unwrap().clone());
        mapped.push((flow.netlist_fingerprint(), flow.netlist_shared().unwrap()));
        ids.push(e.id);
    }

    // Solo references, one run per member.
    let solo: Vec<LaneActivityReport> = (0..designs.len())
        .map(|m| {
            power::measure_activity_batch_wide::<W>(
                &mapped[m].1.netlist,
                &designs[m],
                activations_of(m),
                &seeds_of::<W>(m),
                None,
            )
        })
        .collect();

    let members: Vec<(u64, &Netlist)> =
        mapped.iter().map(|(fp, m)| (*fp, &m.netlist)).collect();
    for &k in shard_counts {
        let art = ensure_fused(None, &members, k);
        let plan = &art.plan;
        let mut sim = ShardSim::<W>::new(&art.fused, plan);
        let stims: Vec<MemberStim<'_>> = (0..designs.len())
            .map(|m| MemberStim {
                design: &designs[m],
                activations: activations_of(m),
                seeds: seeds_of::<W>(m),
            })
            .collect();
        let reports = measure_fused_activity(&mut sim, &stims);
        assert_eq!(reports.len(), solo.len());
        for (m, (got, want)) in reports.iter().zip(&solo).enumerate() {
            assert_eq!(got.cycles, want.cycles, "{}: K={k} cycle count", ids[m]);
            assert_eq!(got.activations, want.activations, "{}: K={k} activations", ids[m]);
            assert_eq!(got.lanes, want.lanes, "{}: K={k} per-lane toggle rates", ids[m]);
            // The power figures the serving path reports are derived
            // from these reports; spot-check the derivation end to end.
            for lane in [0, W::LANES / 2, W::LANES - 1] {
                for f_hz in [6.0e6, 12.0e6] {
                    assert_eq!(
                        power::average_power_mw(&ICE40, &got.lane(lane), f_hz),
                        power::average_power_mw(&ICE40, &want.lane(lane), f_hz),
                        "{}: K={k} lane {lane} power at {f_hz} Hz",
                        ids[m]
                    );
                }
            }
        }
        // Exchange-counter sanity: every owned cut word gets exactly
        // one publication opportunity per simulated cycle, and the
        // dirty filter can never publish more than every cut word in
        // every sync phase.
        let stats = sim.exchange_stats();
        let cycles = sim.cycles();
        assert_eq!(
            stats.owner_cut_words.iter().sum::<u64>(),
            stats.cut_words as u64,
            "K={k}: every cut word has exactly one owner"
        );
        for s in 0..plan.shards {
            assert_eq!(
                stats.published[s] + stats.skipped[s],
                stats.owner_cut_words[s] * cycles,
                "K={k} shard {s}: one publication opportunity per owned word per cycle"
            );
        }
        assert!(
            stats.total_published() <= stats.cut_words as u64 * stats.phases,
            "K={k}: published {} exceeds cut words {} x phases {}",
            stats.total_published(),
            stats.cut_words,
            stats.phases
        );
        if k > solo.len() {
            // More shards than members forces member splits, so cut
            // words must exist and live stimulus must exchange some.
            assert!(stats.cut_words > 0, "K={k} over {} members must cut", solo.len());
            assert!(stats.total_published() > 0, "K={k}: live members exchange words");
        }
        eprintln!(
            "K={k} x {} lanes: {} members bit-identical to solo ({} comb cuts, {} reg cuts, \
             cut cost {} after -{} refinement, {}/{} cut words published over {} cycles)",
            W::LANES,
            solo.len(),
            plan.cuts.comb_cuts.len(),
            plan.cuts.reg_cuts.len(),
            plan.cut_cost(),
            plan.refinement.removed(),
            stats.total_published(),
            stats.cut_words as u64 * cycles,
            cycles
        );
    }
}

#[test]
fn fused_sharded_matches_solo_64_lanes() {
    fused_sharded_matches_solo_impl::<u64>(&[1, 2, 4, 8]);
}

#[test]
fn fused_sharded_matches_solo_256_lanes() {
    fused_sharded_matches_solo_impl::<W256>(&[1, 2, 4, 8]);
}

#[test]
fn fused_sharded_matches_solo_512_lanes() {
    fused_sharded_matches_solo_impl::<W512>(&[1, 2, 4, 8]);
}

#[test]
fn idle_member_reports_zero_and_does_not_perturb_others() {
    // A member with zero activations idles: it must report zero
    // activity, and the busy member's report must still be its solo run
    // verbatim (the idle member's nets never toggle into the cuts).
    let mut busy = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
    let busy_design = busy.rtl().unwrap().clone();
    let busy_fp = busy.netlist_fingerprint();
    let busy_mapped = busy.netlist_shared().unwrap();
    let mut idle = Flow::for_system("spring_mass", FlowConfig::default()).unwrap();
    let idle_design = idle.rtl().unwrap().clone();
    let idle_fp = idle.netlist_fingerprint();
    let idle_mapped = idle.netlist_shared().unwrap();

    let solo = power::measure_activity_batch_wide::<u64>(
        &busy_mapped.netlist,
        &busy_design,
        3,
        &seeds_of::<u64>(0),
        None,
    );

    let members: Vec<(u64, &Netlist)> =
        vec![(busy_fp, &busy_mapped.netlist), (idle_fp, &idle_mapped.netlist)];
    let art = ensure_fused(None, &members, 2);
    let plan = ShardPlan::partition(&art.fused, 2);
    let mut sim = ShardSim::<u64>::new(&art.fused, &plan);
    let stims = vec![
        MemberStim { design: &busy_design, activations: 3, seeds: seeds_of::<u64>(0) },
        MemberStim { design: &idle_design, activations: 0, seeds: seeds_of::<u64>(1) },
    ];
    let reports = measure_fused_activity(&mut sim, &stims);

    assert_eq!(reports[0].cycles, solo.cycles, "busy member cycle count");
    assert_eq!(reports[0].lanes, solo.lanes, "busy member toggle rates");
    assert_eq!(reports[1].cycles, 0, "idle member cycles");
    assert_eq!(reports[1].activations, 0, "idle member activations");
    assert!(reports[1].lanes.iter().all(|&r| r == 0.0), "idle member activity");
}
