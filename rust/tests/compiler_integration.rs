//! Integration tests over the compiler surface: Newton source in →
//! Π analysis → RTL → Verilog → gates, driven through the [`Flow`]
//! compilation-session API (the public front door).

use dimsynth::fixedpoint::{QFormat, Q16_15};
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton::{self, corpus};
use dimsynth::rtl;
use dimsynth::synth;

/// A user-authored spec (not from the corpus) exercising custom derived
/// signals, constants, and target selection end to end.
const ORIFICE: &str = r#"
density   : signal = { derivation = mass / (distance ** 3); }
flow_rate : signal = { derivation = (distance ** 3) / time; }
area_sig  : signal = { derivation = distance ** 2; }

orifice : invariant(q_flow : flow_rate,
                    area   : area_sig,
                    dp     : pressure,
                    rho    : density) = {
    (q_flow ** 2) * rho ~ (area ** 2) * dp
}
"#;

#[test]
fn custom_spec_compiles_to_hardware() {
    let mut flow = Flow::from_source("orifice", ORIFICE, "q_flow", FlowConfig::default());
    {
        let analysis = flow.pis().unwrap();
        assert!(analysis.n() >= 1);
        // q_flow isolated.
        for (i, g) in analysis.groups.iter().enumerate() {
            let e = g.exponents[analysis.target];
            if i == analysis.target_group {
                assert_ne!(e, 0);
            } else {
                assert_eq!(e, 0);
            }
        }
    }
    assert!(flow.verilog().unwrap().contains("module pi_compute_orifice ("));
    let design = flow.rtl().unwrap().clone();
    let mapped = flow.netlist().unwrap();
    assert!(mapped.lut4_cells > 100);
    // The mapped design still computes: all-ones input → all Π = 1.
    let mut sim = synth::GateSim::new(&mapped.netlist);
    for p in &design.ports {
        sim.set_bus(&format!("in_{}", p.name), Q16_15.one());
    }
    sim.set_bus("start", 1);
    sim.step();
    sim.set_bus("start", 0);
    let mut guard = 0;
    while !sim.get_bit("done") {
        sim.step();
        guard += 1;
        assert!(guard < 2000);
    }
    for u in 0..design.num_outputs() {
        assert_eq!(sim.get_output(&format!("pi_{u}")), Q16_15.one());
    }
}

#[test]
fn whole_corpus_verilog_emission_is_stable() {
    // Emission must be deterministic (same input → same text) and
    // structurally sane for every system. The second emission goes
    // through `rtl::verilog` directly so the comparison is against a
    // fresh render, not the session's memoized copy.
    for e in corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let v1 = flow.verilog().unwrap().to_string();
        let v2 = rtl::verilog::emit(flow.rtl().unwrap());
        assert_eq!(v1, v2, "{}: nondeterministic emission", e.id);
        assert_eq!(
            v1.matches("\nmodule ").count() + usize::from(v1.starts_with("module")),
            v1.matches("endmodule").count(),
            "{}: unbalanced modules",
            e.id
        );
    }
}

#[test]
fn format_parametricity_whole_flow() {
    // The entire flow (analysis → RTL → gates → timing) works at
    // non-default formats, and resources scale monotonically with width.
    // One session serves all formats: the parse and Π-search stages stay
    // cached while `set_qformat` invalidates RTL and downstream.
    let mut flow = Flow::for_system("vibrating_string", FlowConfig::default()).unwrap();
    let mut last_cells = 0usize;
    for (i, f) in [(8u32, 7u32), (16, 15), (20, 19)] {
        let q = QFormat::new(i, f);
        flow.set_qformat(q);
        let cells = flow.netlist().unwrap().lut4_cells;
        assert!(
            cells > last_cells,
            "cells must grow with width: {cells} !> {last_cells}"
        );
        last_cells = cells;
        let t = flow.timing().unwrap();
        assert!(t.fmax_mhz > 5.0);
        let expected = {
            let d = flow.rtl().unwrap();
            rtl::run_once(d, &vec![q.one(); d.num_inputs()]).cycles
        };
        assert_eq!(flow.latency().unwrap(), expected);
    }
    let counts = flow.counts();
    assert_eq!((counts.parsed, counts.pis), (1, 1), "upstream stages must stay cached");
    assert_eq!(counts.rtl, 3, "each format rebuilds RTL once");
}

#[test]
fn file_based_specs_compile() {
    // The shipped .nt examples exercise the electrical (current) and
    // thermal (temperature) base dimensions through the file flow.
    for (path, target, expect_n) in [
        ("examples/systems/rc_circuit.nt", "f_corner", 1usize),
        ("examples/systems/heat_conduction.nt", "t_inner", 2),
    ] {
        let src = std::fs::read_to_string(path).unwrap();
        let mut flow = Flow::from_source(path, &src, target, FlowConfig::default());
        assert_eq!(flow.pis().unwrap().n(), expect_n, "{path}");
        let d = flow.rtl().unwrap();
        let r = rtl::run_once(d, &vec![Q16_15.one(); d.num_inputs()]);
        assert!(r.outputs.iter().all(|&o| o == Q16_15.one()), "{path}");
    }
}

#[test]
fn dimensional_error_reporting() {
    // Inhomogeneous relations and unknown signals produce errors with
    // positions, not panics — through the session API as well as the
    // frontend directly.
    let bad_rel = "s : invariant(h: distance, t: time) = { h ~ t }";
    let err = newton::load(bad_rel).unwrap_err().to_string();
    assert!(err.contains("homogeneous"), "{err}");
    let mut flow = Flow::from_source("bad", bad_rel, "h", FlowConfig::default());
    assert!(flow.parsed().is_err());

    let unknown = "s : invariant(x: flux_capacitance) = { }";
    let err = newton::load(unknown).unwrap_err().to_string();
    assert!(err.contains("flux_capacitance"), "{err}");
}

#[test]
fn nonparticipating_symbols_are_dropped_from_ports() {
    // Pendulum bob mass and spring-mass gravity cannot join any Π.
    for (id, dropped) in [("pendulum", "bobmass"), ("spring_mass", "g")] {
        let mut flow = Flow::for_system(id, FlowConfig::default()).unwrap();
        let d = flow.rtl().unwrap();
        assert!(
            d.dropped_symbols.iter().any(|s| s == dropped),
            "{id}: expected `{dropped}` dropped, got {:?}",
            d.dropped_symbols
        );
        assert!(d.ports.iter().all(|p| p.name != dropped));
    }
}

#[test]
fn export_roundtrips_through_design() {
    // The JSON export (consumed by aot.py) must agree with the design the
    // RTL backend builds.
    for e in corpus() {
        let ex = dimsynth::report::export::export_system(e.id, Q16_15).unwrap();
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let latency = flow.latency().unwrap();
        let d = flow.rtl().unwrap();
        assert_eq!(ex.ports.len(), d.num_inputs(), "{}", e.id);
        assert_eq!(ex.exponents.len(), d.num_outputs(), "{}", e.id);
        for (ue, de) in ex.exponents.iter().zip(d.units.iter()) {
            assert_eq!(ue, &de.exponents, "{}", e.id);
        }
        assert_eq!(ex.latency, latency);
    }
}
