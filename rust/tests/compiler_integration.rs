//! Integration tests over the compiler surface: Newton source in →
//! Π analysis → RTL → Verilog → gates, through the public API only.

use dimsynth::fixedpoint::{QFormat, Q16_15};
use dimsynth::newton::{self, corpus};
use dimsynth::pisearch;
use dimsynth::rtl::{self, Policy};
use dimsynth::synth;
use dimsynth::timing;

/// A user-authored spec (not from the corpus) exercising custom derived
/// signals, constants, and target selection end to end.
const ORIFICE: &str = r#"
density   : signal = { derivation = mass / (distance ** 3); }
flow_rate : signal = { derivation = (distance ** 3) / time; }
area_sig  : signal = { derivation = distance ** 2; }

orifice : invariant(q_flow : flow_rate,
                    area   : area_sig,
                    dp     : pressure,
                    rho    : density) = {
    (q_flow ** 2) * rho ~ (area ** 2) * dp
}
"#;

#[test]
fn custom_spec_compiles_to_hardware() {
    let models = newton::load(ORIFICE).unwrap();
    assert_eq!(models.len(), 1);
    let analysis = pisearch::analyze_optimized(&models[0], "q_flow").unwrap();
    assert!(analysis.n() >= 1);
    // q_flow isolated.
    for (i, g) in analysis.groups.iter().enumerate() {
        let e = g.exponents[analysis.target];
        if i == analysis.target_group {
            assert_ne!(e, 0);
        } else {
            assert_eq!(e, 0);
        }
    }
    let design = rtl::build(&analysis, Q16_15);
    let v = rtl::verilog::emit(&design);
    assert!(v.contains("module pi_compute_orifice ("));
    let mapped = synth::map_design(&design);
    assert!(mapped.lut4_cells > 100);
    // The mapped design still computes: all-ones input → all Π = 1.
    let mut sim = synth::GateSim::new(&mapped.netlist);
    for p in &design.ports {
        sim.set_bus(&format!("in_{}", p.name), Q16_15.one());
    }
    sim.set_bus("start", 1);
    sim.step();
    sim.set_bus("start", 0);
    let mut guard = 0;
    while !sim.get_bit("done") {
        sim.step();
        guard += 1;
        assert!(guard < 2000);
    }
    for u in 0..design.num_outputs() {
        assert_eq!(sim.get_output(&format!("pi_{u}")), Q16_15.one());
    }
}

#[test]
fn whole_corpus_verilog_emission_is_stable() {
    // Emission must be deterministic (same input → same text) and
    // structurally sane for every system.
    for e in corpus() {
        let m = newton::load_entry(&e).unwrap();
        let a = pisearch::analyze_optimized(&m, e.target).unwrap();
        let d = rtl::build(&a, Q16_15);
        let v1 = rtl::verilog::emit(&d);
        let v2 = rtl::verilog::emit(&d);
        assert_eq!(v1, v2, "{}: nondeterministic emission", e.id);
        assert_eq!(
            v1.matches("\nmodule ").count() + usize::from(v1.starts_with("module")),
            v1.matches("endmodule").count(),
            "{}: unbalanced modules",
            e.id
        );
    }
}

#[test]
fn format_parametricity_whole_flow() {
    // The entire flow (analysis → RTL → gates → timing) works at
    // non-default formats, and resources scale monotonically with width.
    let e = newton::by_id("vibrating_string").unwrap();
    let m = newton::load_entry(&e).unwrap();
    let a = pisearch::analyze_optimized(&m, e.target).unwrap();
    let mut last_cells = 0usize;
    for (i, f) in [(8u32, 7u32), (16, 15), (20, 19)] {
        let q = QFormat::new(i, f);
        let d = rtl::build(&a, q);
        let mapped = synth::map_design(&d);
        assert!(
            mapped.lut4_cells > last_cells,
            "cells must grow with width: {} !> {}",
            mapped.lut4_cells,
            last_cells
        );
        last_cells = mapped.lut4_cells;
        let t = timing::analyze(&mapped.netlist, &timing::ICE40_LP);
        assert!(t.fmax_mhz > 5.0);
        assert_eq!(
            rtl::module_latency(&d, Policy::ParallelPerPi),
            rtl::run_once(&d, &vec![q.one(); d.num_inputs()]).cycles
        );
    }
}

#[test]
fn file_based_specs_compile() {
    // The shipped .nt examples exercise the electrical (current) and
    // thermal (temperature) base dimensions through the file flow.
    for (path, target, expect_n) in [
        ("examples/systems/rc_circuit.nt", "f_corner", 1usize),
        ("examples/systems/heat_conduction.nt", "t_inner", 2),
    ] {
        let src = std::fs::read_to_string(path).unwrap();
        let models = newton::load(&src).unwrap();
        let a = pisearch::analyze_optimized(&models[0], target).unwrap();
        assert_eq!(a.n(), expect_n, "{path}");
        let d = rtl::build(&a, Q16_15);
        let r = rtl::run_once(&d, &vec![Q16_15.one(); d.num_inputs()]);
        assert!(r.outputs.iter().all(|&o| o == Q16_15.one()), "{path}");
    }
}

#[test]
fn dimensional_error_reporting() {
    // Inhomogeneous relations and unknown signals produce errors with
    // positions, not panics.
    let bad_rel = "s : invariant(h: distance, t: time) = { h ~ t }";
    let err = newton::load(bad_rel).unwrap_err().to_string();
    assert!(err.contains("homogeneous"), "{err}");

    let unknown = "s : invariant(x: flux_capacitance) = { }";
    let err = newton::load(unknown).unwrap_err().to_string();
    assert!(err.contains("flux_capacitance"), "{err}");
}

#[test]
fn nonparticipating_symbols_are_dropped_from_ports() {
    // Pendulum bob mass and spring-mass gravity cannot join any Π.
    for (id, dropped) in [("pendulum", "bobmass"), ("spring_mass", "g")] {
        let e = newton::by_id(id).unwrap();
        let m = newton::load_entry(&e).unwrap();
        let a = pisearch::analyze_optimized(&m, e.target).unwrap();
        let d = rtl::build(&a, Q16_15);
        assert!(
            d.dropped_symbols.iter().any(|s| s == dropped),
            "{id}: expected `{dropped}` dropped, got {:?}",
            d.dropped_symbols
        );
        assert!(d.ports.iter().all(|p| p.name != dropped));
    }
}

#[test]
fn export_roundtrips_through_design() {
    // The JSON export (consumed by aot.py) must agree with the design the
    // RTL backend builds.
    for e in corpus() {
        let ex = dimsynth::report::export::export_system(e.id, Q16_15).unwrap();
        let m = newton::load_entry(&e).unwrap();
        let a = pisearch::analyze_optimized(&m, e.target).unwrap();
        let d = rtl::build(&a, Q16_15);
        assert_eq!(ex.ports.len(), d.num_inputs(), "{}", e.id);
        assert_eq!(ex.exponents.len(), d.num_outputs(), "{}", e.id);
        for (ue, de) in ex.exponents.iter().zip(d.units.iter()) {
            assert_eq!(ue, &de.exponents, "{}", e.id);
        }
        assert_eq!(ex.latency, rtl::module_latency(&d, Policy::ParallelPerPi));
    }
}
