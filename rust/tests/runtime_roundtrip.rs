//! Runtime integration: the AOT artifacts (Pallas Π kernel, Φ model)
//! executed through PJRT must agree with the native implementations.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not been
//! built; `make test` always builds it first.

use dimsynth::fixedpoint::{self, Q16_15};
use dimsynth::newton::corpus;
use dimsynth::report::export::export_system;
use dimsynth::runtime::{engine, Engine};
use dimsynth::stim::{self, Lfsr32};
use dimsynth::train::{self, FeatureKind};

fn artifacts_ready() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

#[test]
fn pi_artifacts_bit_exact_vs_native_all_systems() {
    if !artifacts_ready() {
        return;
    }
    let mut eng = Engine::new("artifacts").unwrap();
    let mut rng = Lfsr32::new(0xAB5);
    for e in corpus() {
        let ex = export_system(e.id, Q16_15).unwrap();
        let kp = ex.ports.len();
        let n = ex.exponents.len();
        let exe = eng.load(&format!("pi_{}_b64", e.id)).unwrap();
        // Random physical samples + adversarial rows (zeros, extremes).
        let mut flat = vec![0i64; 64 * kp];
        for j in 0..64 {
            for p in 0..kp {
                flat[j * kp + p] = match j {
                    0 => 0,
                    1 => Q16_15.max_raw(),
                    2 => Q16_15.min_raw(),
                    _ => Q16_15.from_f64(rng.range(-16.0, 16.0)),
                };
            }
        }
        let outs = exe.run(&[engine::i32_matrix(64, kp, &flat).unwrap()]).unwrap();
        let got = engine::to_i32s(&outs[0]).unwrap();
        for j in 0..64 {
            let row = &flat[j * kp..(j + 1) * kp];
            for (gi, exps) in ex.exponents.iter().enumerate() {
                let native = fixedpoint::eval_monomial(Q16_15, row, exps);
                assert_eq!(
                    got[j * n + gi] as i64,
                    native,
                    "{}: sample {j} group {gi} inputs {row:?}",
                    e.id
                );
            }
        }
    }
}

#[test]
fn pi_b1_artifact_matches_b64() {
    if !artifacts_ready() {
        return;
    }
    let mut eng = Engine::new("artifacts").unwrap();
    let ex = export_system("beam", Q16_15).unwrap();
    let kp = ex.ports.len();
    let b1 = eng.load("pi_beam_b1").unwrap();
    let b64 = eng.load("pi_beam_b64").unwrap();
    let mut rng = Lfsr32::new(9);
    let sample: Vec<i64> = (0..kp).map(|_| Q16_15.from_f64(rng.range(0.5, 9.0))).collect();
    let o1 = b1.run(&[engine::i32_matrix(1, kp, &sample).unwrap()]).unwrap();
    let mut flat = vec![0i64; 64 * kp];
    flat[..kp].copy_from_slice(&sample);
    let o64 = b64.run(&[engine::i32_matrix(64, kp, &flat).unwrap()]).unwrap();
    let v1 = engine::to_i32s(&o1[0]).unwrap();
    let v64 = engine::to_i32s(&o64[0]).unwrap();
    assert_eq!(v1[..ex.exponents.len()], v64[..ex.exponents.len()]);
}

#[test]
fn pipeline_artifact_consistent_with_stagewise() {
    if !artifacts_ready() {
        return;
    }
    // pipeline_<id>_b64 (fused Π + Φ) must equal pi → features → phi_infer.
    let mut eng = Engine::new("artifacts").unwrap();
    let system = "unpowered_flight";
    let ex = export_system(system, Q16_15).unwrap();
    let kp = ex.ports.len();
    let n = ex.exponents.len();
    let dim = (n - 1).max(1);
    let p = train::param_count(dim);
    // Arbitrary but fixed parameters/stats.
    let params = train::init_params(dim, 0x77);
    let shift = vec![0.5f32; dim];
    let scale = vec![2.0f32; dim];

    let mut rng = Lfsr32::new(0x42);
    let mut flat = vec![0i64; 64 * kp];
    for v in flat.iter_mut() {
        *v = Q16_15.from_f64(rng.range(0.5, 8.0));
    }

    let fused = eng.load(&format!("pipeline_{system}_b64")).unwrap();
    let out_fused = fused
        .run(&[
            engine::f32_vec(&params),
            engine::i32_matrix(64, kp, &flat).unwrap(),
            engine::f32_vec(&shift),
            engine::f32_vec(&scale),
        ])
        .unwrap();
    let fused_pred = engine::to_f32s(&out_fused[0]).unwrap();

    // Stagewise.
    let pi = eng.load(&format!("pi_{system}_b64")).unwrap();
    let pis =
        engine::to_i32s(&pi.run(&[engine::i32_matrix(64, kp, &flat).unwrap()]).unwrap()[0])
            .unwrap();
    let mut feats = vec![0f32; 64 * dim];
    for j in 0..64 {
        for d in 0..dim {
            feats[j * dim + d] = if n > 1 {
                Q16_15.to_f64(pis[j * n + d + 1] as i64) as f32
            } else {
                1.0
            };
        }
    }
    let infer = eng.load(&format!("phi_infer_{system}_b64")).unwrap();
    let staged = engine::to_f32s(
        &infer
            .run(&[
                engine::f32_vec(&params),
                engine::f32_matrix(64, dim, &feats).unwrap(),
                engine::f32_vec(&shift),
                engine::f32_vec(&scale),
            ])
            .unwrap()[0],
    )
    .unwrap();
    for j in 0..64 {
        assert!(
            (fused_pred[j] - staged[j]).abs() < 1e-5,
            "sample {j}: fused {} vs staged {}",
            fused_pred[j],
            staged[j]
        );
    }
    let _ = p;
}

#[test]
fn train_step_descends_on_learnable_problem() {
    if !artifacts_ready() {
        return;
    }
    // Beam: Π₀ is a clean function of Π₁ — 200 steps must cut the loss by
    // an order of magnitude from the first recorded value.
    let mut eng = Engine::new("artifacts").unwrap();
    let ds = train::build_dataset("beam", FeatureKind::Pi, 512, 0.0, 0xD0E).unwrap();
    let out = train::train_on(&mut eng, &ds, "beam", 200, 0.2, 0xD0E).unwrap();
    let first = out.loss_curve[0];
    assert!(
        out.final_loss < first / 10.0,
        "no descent: first {first}, final {}",
        out.final_loss
    );
}

#[test]
fn target_recovery_error_small_after_training() {
    if !artifacts_ready() {
        return;
    }
    let mut eng = Engine::new("artifacts").unwrap();
    let ds = train::build_dataset("spring_mass", FeatureKind::Pi, 512, 0.0, 0xF0).unwrap();
    let out = train::train_on(&mut eng, &ds, "spring_mass", 300, 0.2, 0xF0).unwrap();
    let err =
        train::eval_target_error(&mut eng, &ds, "spring_mass", &out.params, 128, 3).unwrap();
    assert!(err < 0.02, "spring-constant recovery error {err}");
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let mut eng = Engine::new("artifacts").unwrap();
    let err = match eng.load("no_such_artifact") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected an error"),
    };
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn engine_caches_compilations() {
    if !artifacts_ready() {
        return;
    }
    let mut eng = Engine::new("artifacts").unwrap();
    let t0 = std::time::Instant::now();
    let _ = eng.load("pi_pendulum_b1").unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = eng.load("pi_pendulum_b1").unwrap();
    let warm = t1.elapsed();
    assert!(warm < cold / 10, "cache ineffective: cold {cold:?}, warm {warm:?}");
}

#[test]
fn quantized_trace_pis_match_f64_within_tolerance() {
    if !artifacts_ready() {
        return;
    }
    // Physical sanity across the runtime path: Π from quantized signals
    // through the artifact ≈ Π from f64 math.
    let mut eng = Engine::new("artifacts").unwrap();
    let mut rng = Lfsr32::new(0xC4);
    for e in corpus() {
        let ex = export_system(e.id, Q16_15).unwrap();
        let kp = ex.ports.len();
        let n = ex.exponents.len();
        let exe = eng.load(&format!("pi_{}_b64", e.id)).unwrap();
        let mut flat = vec![0i64; 64 * kp];
        let mut f64rows = Vec::new();
        for j in 0..64 {
            let s = stim::sample(e.id, &mut rng).unwrap();
            let row: Vec<f64> = ex.ports.iter().map(|&si| s[si]).collect();
            for (p, v) in row.iter().enumerate() {
                flat[j * kp + p] = Q16_15.from_f64(*v);
            }
            f64rows.push(row);
        }
        let outs = exe.run(&[engine::i32_matrix(64, kp, &flat).unwrap()]).unwrap();
        let got = engine::to_i32s(&outs[0]).unwrap();
        let limit = 0.8 * Q16_15.max_value();
        for (j, row) in f64rows.iter().enumerate() {
            for (gi, exps) in ex.exponents.iter().enumerate() {
                // Follow the serial schedule in f64 and skip groups whose
                // intermediates leave the representable range — there the
                // hardware saturates by design (e.g. the fluid-pipe
                // μ⁻² group with water-like signals).
                let mut acc = f64::NAN;
                let mut in_range = true;
                for op in fixedpoint::monomial_ops(exps) {
                    acc = match op {
                        fixedpoint::MonOp::Load(i) => row[i],
                        fixedpoint::MonOp::LoadOne => 1.0,
                        fixedpoint::MonOp::Mul(i) => acc * row[i],
                        fixedpoint::MonOp::Div(i) => acc / row[i],
                    };
                    if acc.abs() > limit {
                        in_range = false;
                        break;
                    }
                }
                if !in_range {
                    continue;
                }
                let truth = acc;
                let fx = Q16_15.to_f64(got[j * n + gi] as i64);
                assert!(
                    (fx - truth).abs() < 0.02 * truth.abs().max(1.0),
                    "{}: group {gi} fx {fx} vs f64 {truth}",
                    e.id
                );
            }
        }
    }
}
