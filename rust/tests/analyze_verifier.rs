//! Injected-defect fixtures for the static verifier (`analyze/`).
//!
//! Each test plants exactly one class of defect in an otherwise-valid
//! artifact and asserts the verifier reports the *exact* diagnostic
//! code for it — no panic, no cascade, no false neighbors:
//!
//! * combinational loop          → `AN103` (error)
//! * double-driven net           → `AN101` (error)
//! * dropped cut entry           → `AN402` (error)
//! * corrupted scatter index     → `AN404` (error)
//! * Q-format below proven range → `AN203` (warning)
//!
//! The pristine half: every corpus system must analyze clean at the
//! default Q16.15 config (memoized — the report is computed once per
//! session), and the fused whole-corpus shard plan must pass pre-flight
//! at every K ∈ {1, 2, 4, 8}.

use dimsynth::analyze::{lint_netlist, preflight_plan, DiagCode, Severity};
use dimsynth::fixedpoint::QFormat;
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton::corpus;
use dimsynth::shard::{FusedNetlist, ShardPlan};
use dimsynth::synth::{Netlist, Node};

/// Compile every corpus system down to its mapped netlist.
fn corpus_netlists() -> Vec<Netlist> {
    corpus()
        .iter()
        .map(|entry| {
            let mut flow = Flow::for_system(entry.id, FlowConfig::default()).unwrap();
            flow.netlist().unwrap().netlist.clone()
        })
        .collect()
}

fn fused_corpus() -> FusedNetlist {
    let netlists = corpus_netlists();
    let refs: Vec<&Netlist> = netlists.iter().collect();
    FusedNetlist::fuse_refs(&refs)
}

// ---------------------------------------------------------------------
// Injected structural defects (pass 1).
// ---------------------------------------------------------------------

#[test]
fn injected_comb_loop_is_exactly_an103() {
    // Three LUTs in a ring feeding a real output. The builder API cannot
    // express this (construction is topological), so the fixture goes
    // through `from_parts` — the same door a corrupt store artifact or a
    // buggy optimization pass would use.
    let nodes = vec![
        Node::Input("a".into()),
        Node::Lut { ins: vec![0, 2], tt: 0b0110 },
        Node::Lut { ins: vec![3], tt: 0b01 },
        Node::Lut { ins: vec![1], tt: 0b01 },
    ];
    let nl = Netlist::from_parts(
        nodes,
        vec![("y".into(), vec![3])],
        vec![("a".into(), vec![0])],
    );
    let diags = lint_netlist(&nl);
    assert_eq!(diags.len(), 1, "{diags:?}");
    let d = &diags[0];
    assert_eq!(d.code, DiagCode::CombLoop);
    assert_eq!(d.code.as_str(), "AN103");
    assert_eq!(d.severity, Severity::Error);
    assert!(
        d.message.contains("1 -> 2 -> 3 -> 1"),
        "cycle path should be spelled out: {}",
        d.message
    );
}

#[test]
fn injected_double_driven_net_is_exactly_an101() {
    // An input-bus bit bound onto a LUT output: the binding would
    // clobber a logic driver every cycle.
    let nodes = vec![
        Node::Input("a".into()),
        Node::Lut { ins: vec![0], tt: 0b01 },
    ];
    let nl = Netlist::from_parts(
        nodes,
        vec![("y".into(), vec![1])],
        vec![("a".into(), vec![0]), ("b".into(), vec![1])],
    );
    let diags = lint_netlist(&nl);
    assert_eq!(diags.len(), 1, "{diags:?}");
    let d = &diags[0];
    assert_eq!(d.code, DiagCode::MultiDriver);
    assert_eq!(d.code.as_str(), "AN101");
    assert_eq!(d.severity, Severity::Error);
    assert!(d.message.contains("driven by a LUT"), "{}", d.message);
}

// ---------------------------------------------------------------------
// Injected plan defects (pass 4) — against the real fused corpus.
// ---------------------------------------------------------------------

#[test]
fn dropped_cut_entry_on_fused_corpus_is_exactly_an402() {
    let fused = fused_corpus();
    let mut plan = ShardPlan::partition(&fused, 4);
    assert!(plan.cut_cost() > 0, "K=4 corpus plan should have cut traffic");

    let dropped = if let Some(c) = plan.cuts.reg_cuts.pop() {
        c
    } else if let Some(c) = plan.cuts.comb_cuts.pop() {
        c
    } else {
        plan.cuts.dff_cuts.pop().expect("plan with cut_cost > 0 has an entry")
    };
    // Keep the refine report consistent so the *only* defect visible is
    // the missing entry — the test pins AN402, not AN405.
    plan.refinement.refined_cut_cost = plan.cut_cost();

    let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
    assert_eq!(diags.len(), 1, "{diags:?}");
    let d = &diags[0];
    assert_eq!(d.code, DiagCode::MissingCut);
    assert_eq!(d.code.as_str(), "AN402");
    assert_eq!(d.severity, Severity::Error);
    assert!(
        d.message.contains(&format!("net {}", dropped.net)),
        "finding should name the dropped net: {}",
        d.message
    );
}

#[test]
fn corrupted_scatter_index_on_fused_corpus_is_an404() {
    let fused = fused_corpus();
    let plan = ShardPlan::partition(&fused, 4);
    let mut members = fused.members.clone();
    members[1].net_range.0 += 1; // gap: member ranges no longer tile

    let diags = preflight_plan(&fused.netlist, &members, &plan);
    assert!(!diags.is_empty());
    assert!(
        diags.iter().all(|d| d.code == DiagCode::ScatterCorrupt),
        "{diags:?}"
    );
    assert_eq!(diags[0].code.as_str(), "AN404");
    assert_eq!(diags[0].severity, Severity::Error);
}

// ---------------------------------------------------------------------
// Injected Q-format defect (pass 2) — through the real flow stage.
// ---------------------------------------------------------------------

#[test]
fn shrunk_qformat_flags_unrepresentable_constant_an203() {
    // Q3.2 tops out at 7.75; the pendulum's Newton model carries
    // g = 9.80665 as a compiled-in constant, so the proven range of the
    // constant no longer fits the format. A warning, not an error: the
    // constant saturates deterministically, it does not corrupt state.
    let config = FlowConfig { qformat: QFormat::new(3, 2), ..FlowConfig::default() };
    let mut flow = Flow::for_system("pendulum", config).unwrap();
    let report = flow.analysis().unwrap();
    let hits: Vec<_> = report
        .diagnostics
        .iter()
        .filter(|d| d.code == DiagCode::QConstUnrepresentable)
        .collect();
    assert!(!hits.is_empty(), "{:?}", report.diagnostics);
    assert_eq!(hits[0].code.as_str(), "AN203");
    assert_eq!(hits[0].severity, Severity::Warning);
    assert!(
        !report.has_errors(),
        "interval findings are warnings; nothing here should block boot: {:?}",
        report.diagnostics
    );
}

// ---------------------------------------------------------------------
// Pristine corpus: clean everywhere, computed once.
// ---------------------------------------------------------------------

#[test]
fn pristine_corpus_analyzes_clean_and_memoized() {
    for entry in corpus() {
        let mut flow = Flow::for_system(entry.id, FlowConfig::default()).unwrap();
        let report = flow.analysis().unwrap();
        assert!(
            report.is_clean(),
            "{}: pristine corpus must lint clean: {:?}",
            entry.id,
            report.diagnostics
        );
        assert_eq!(report.system, entry.id);
        assert_eq!(flow.counts().analyze, 1, "{}", entry.id);
        // Re-query is a memo hit, not a recompute.
        let again = flow.analysis().unwrap();
        assert!(again.is_clean());
        assert_eq!(flow.counts().analyze, 1, "{}: analysis must be memoized", entry.id);
    }
}

#[test]
fn pristine_fused_corpus_preflights_clean_at_every_k() {
    let fused = fused_corpus();
    for k in [1usize, 2, 4, 8] {
        let plan = ShardPlan::partition(&fused, k);
        let diags = preflight_plan(&fused.netlist, &fused.members, &plan);
        assert!(diags.is_empty(), "K={k}: {diags:?}");
    }
}
