//! End-to-end serving tests: train → serve → verify online accuracy and
//! coordinator behaviour (batching, concurrency, shutdown), plus the
//! multi-system path — many endpoints on one warm [`ServeSet`], warm
//! reboots from a shared artifact store, and cross-system power
//! batching that is bit-identical to per-system dispatch.
//!
//! The Φ-inference tests need the AOT artifacts (`make artifacts`) and
//! skip without them; the serve-set boot and power-flood tests are pure
//! compilation + gate-level simulation and always run.

use dimsynth::coordinator::{
    estimate_power_requests, serve_synthetic, InferenceServer, PiPath, PowerEstimate,
    PowerRequest, SensorInput, ServeSet, ServerConfig, SystemPowerRequest,
};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::{ArtifactStore, FlowConfig};
use dimsynth::stim::{self, Lfsr32};
use dimsynth::synth::LaneWidth;
use dimsynth::train::{self, FeatureKind};
use std::sync::Arc;
use std::time::Duration;

fn artifacts_ready() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn start_server(system: &str, pi_path: PiPath) -> (InferenceServer, train::TrainOutput) {
    let trained =
        train::run_training("artifacts", system, FeatureKind::Pi, 400, 0x1E57).unwrap();
    let server = InferenceServer::start(
        ServerConfig {
            artifacts: "artifacts".into(),
            system: system.into(),
            max_batch: 32,
            linger: Duration::from_micros(200),
            pi_path,
        },
        trained.clone(),
    )
    .unwrap();
    (server, trained)
}

#[test]
fn serve_synthetic_reports() {
    if !artifacts_ready() {
        return;
    }
    let report = serve_synthetic("artifacts", "pendulum", 256, 32).unwrap();
    assert!(report.contains("throughput"), "{report}");
    assert!(report.contains("pendulum"));
}

#[test]
fn online_accuracy_beam() {
    if !artifacts_ready() {
        return;
    }
    let (server, trained) = start_server("beam", PiPath::Native);
    let export = trained.dataset.export.clone();
    let mut rng = Lfsr32::new(0xE2E);
    let mut pending = Vec::new();
    let mut truths = Vec::new();
    for _ in 0..300 {
        let s = stim::sample("beam", &mut rng).unwrap();
        truths.push(s[export.target_index]);
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut rel = 0f64;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let p = rx.recv().unwrap().unwrap();
        assert!(p.target_estimate.is_finite());
        rel += ((p.target_estimate - truth) / truth).abs();
    }
    let stats = server.shutdown();
    assert_eq!(stats.samples, 300);
    let mean_rel = rel / 300.0;
    assert!(mean_rel < 0.15, "beam online error {mean_rel}");
}

#[test]
fn rtl_sim_path_serves_and_reports_cycles() {
    if !artifacts_ready() {
        return;
    }
    let (server, trained) = start_server("pendulum", PiPath::RtlSim);
    let export = trained.dataset.export.clone();
    let mut rng = Lfsr32::new(0x515);
    let mut pending = Vec::new();
    for _ in 0..32 {
        let s = stim::sample("pendulum", &mut rng).unwrap();
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    for rx in pending {
        let p = rx.recv().unwrap().unwrap();
        // The pendulum module takes 115 cycles per sample.
        assert_eq!(p.hw_cycles, Some(115));
    }
    server.shutdown();
}

#[test]
fn hlo_pi_path_agrees_with_native_in_serving() {
    if !artifacts_ready() {
        return;
    }
    let (native, trained_a) = start_server("unpowered_flight", PiPath::Native);
    let (hlo, trained_b) = start_server("unpowered_flight", PiPath::Hlo);
    // Identical training seeds → identical parameters.
    assert_eq!(trained_a.params, trained_b.params);
    let export = trained_a.dataset.export.clone();
    let mut rng = Lfsr32::new(0x777);
    for _ in 0..16 {
        let s = stim::sample("unpowered_flight", &mut rng).unwrap();
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        let pa = native
            .submit(SensorInput { values_q: values_q.clone() })
            .recv()
            .unwrap()
            .unwrap();
        let pb = hlo.submit(SensorInput { values_q }).recv().unwrap().unwrap();
        assert_eq!(pa.pis, pb.pis, "Π mismatch between native and HLO paths");
        assert!((pa.pi0_pred - pb.pi0_pred).abs() < 1e-5);
    }
    native.shutdown();
    hlo.shutdown();
}

#[test]
fn concurrent_submitters() {
    if !artifacts_ready() {
        return;
    }
    let (server, trained) = start_server("spring_mass", PiPath::Native);
    let export = trained.dataset.export.clone();
    let server = std::sync::Arc::new(server);
    let mut joins = Vec::new();
    for t in 0..4u32 {
        let server = server.clone();
        let export = export.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Lfsr32::new(0x100 + t);
            let mut ok = 0usize;
            for _ in 0..64 {
                let s = stim::sample("spring_mass", &mut rng).unwrap();
                let values_q: Vec<i64> =
                    export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
                let p = server.submit(SensorInput { values_q }).recv().unwrap().unwrap();
                if p.target_estimate.is_finite() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 4 * 64);
    let stats = std::sync::Arc::try_unwrap(server)
        .ok()
        .expect("all submitters done")
        .shutdown();
    assert_eq!(stats.samples, 256);
    assert!(stats.batches >= 8, "batching too coarse: {}", stats.batches);
}

#[test]
fn unknown_system_fails_cleanly() {
    if !artifacts_ready() {
        return;
    }
    let err = serve_synthetic("artifacts", "warp_core", 8, 4).unwrap_err().to_string();
    assert!(err.contains("warp_core"), "{err}");
}

// ---- multi-system serving on one warm ServeSet ---------------------------

fn small_config(width: LaneWidth) -> FlowConfig {
    FlowConfig { power_samples: 2, lane_width: width, ..FlowConfig::default() }
}

/// A restarted serve process pointed at the same `--cache-dir` must
/// boot every previously compiled system warm: zero recomputes, and
/// lazily — only the design + netlist artifacts each endpoint actually
/// serves from, plus the analysis report the boot gate reads, are
/// deserialized.
#[test]
fn serveset_reboots_warm_with_zero_recomputes() {
    let dir = std::env::temp_dir()
        .join(format!("dimsynth-serveset-warm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let systems = ["pendulum", "spring_mass"];

    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let cold = ServeSet::boot(&systems, small_config(LaneWidth::W64), Some(store)).unwrap();
    let cold_counts = cold.total_counts();
    assert!(cold_counts.recomputes() > 0, "cold boot must compile: {cold_counts:?}");
    let cold_cells: Vec<usize> =
        (0..cold.len()).map(|i| cold.handle_at(i).mapped().lut4_cells).collect();
    drop(cold);

    // Fresh process shape: new sessions, re-opened store.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let warm = ServeSet::boot(&systems, small_config(LaneWidth::W64), Some(store)).unwrap();
    let counts = warm.total_counts();
    assert_eq!(counts.recomputes(), 0, "warm serve boot must recompute nothing: {counts:?}");
    // Lazy boot: exactly the rtl + netlist artifacts each endpoint
    // serves from plus the analysis report the boot gate checks —
    // nothing upstream.
    assert_eq!(
        counts.disk_hits,
        3 * systems.len() as u32,
        "warm boot must load only what serving needs: {counts:?}"
    );
    let warm_cells: Vec<usize> =
        (0..warm.len()).map(|i| warm.handle_at(i).mapped().lut4_cells).collect();
    assert_eq!(cold_cells, warm_cells, "warm handles must carry identical hardware");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Cross-system power floods must preserve every per-request result
/// bit-exactly versus single-system dispatch, at both lane widths.
#[test]
fn cross_system_flood_matches_per_system_dispatch_at_both_widths() {
    for width in [LaneWidth::W64, LaneWidth::W256] {
        let set = ServeSet::boot(&["pendulum", "spring_mass"], small_config(width), None)
            .unwrap();
        // Unevenly interleaved flood across the two systems (more than
        // one 64-lane chunk per system at the narrow width).
        let requests: Vec<SystemPowerRequest> = (0..150u32)
            .map(|i| SystemPowerRequest {
                system: (i % 3 == 1) as usize,
                request: PowerRequest {
                    seed: 0x7000 + i,
                    f_hz: if i % 2 == 0 { 6.0e6 } else { 12.0e6 },
                },
            })
            .collect();
        let flood = set.estimate_power_flood(&requests, 2).unwrap();
        assert_eq!(flood.len(), requests.len());

        for sys in 0..set.len() {
            let handle = set.handle_at(sys);
            let own: Vec<PowerRequest> = requests
                .iter()
                .filter(|r| r.system == sys)
                .map(|r| r.request)
                .collect();
            let solo =
                estimate_power_requests(handle.netlist(), handle.design(), &own, 2, width);
            let mixed: Vec<&PowerEstimate> = requests
                .iter()
                .zip(&flood)
                .filter(|(r, _)| r.system == sys)
                .map(|(_, e)| e)
                .collect();
            assert_eq!(solo.len(), mixed.len());
            for (i, (a, b)) in solo.iter().zip(mixed).enumerate() {
                assert_eq!(a.mw, b.mw, "{width:?} system {sys} request {i}");
                assert_eq!(
                    a.toggles_per_cycle, b.toggles_per_cycle,
                    "{width:?} system {sys} request {i}"
                );
                assert_eq!(a.cycles, b.cycles, "{width:?} system {sys} request {i}");
            }
        }
    }
}

/// The asynchronous batcher (channel + linger + cross-system grouped
/// dispatch) must answer a mixed flood with the same estimates as the
/// synchronous path, regardless of how requests landed in batches.
#[test]
fn power_batcher_preserves_per_request_results() {
    let set =
        ServeSet::boot(&["pendulum", "spring_mass"], small_config(LaneWidth::W64), None)
            .unwrap();
    let requests: Vec<SystemPowerRequest> = (0..96u32)
        .map(|i| SystemPowerRequest {
            system: (i % 2) as usize,
            request: PowerRequest { seed: 0x9100 + i, f_hz: 6.0e6 },
        })
        .collect();
    let want = set.estimate_power_flood(&requests, 2).unwrap();

    let batcher = set.power_batcher(Duration::from_micros(200), 2);
    let pending: Vec<_> =
        requests.iter().map(|r| batcher.submit(r.system, r.request)).collect();
    for (i, (rx, want)) in pending.into_iter().zip(&want).enumerate() {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.mw, want.mw, "request {i}");
        assert_eq!(got.toggles_per_cycle, want.toggles_per_cycle, "request {i}");
        assert_eq!(got.cycles, want.cycles, "request {i}");
    }
    let stats = batcher.shutdown();
    assert_eq!(stats.requests, requests.len() as u64, "{stats:?}");
    assert!(!stats.worker_panicked);
    assert!(stats.batches >= 1);
}

/// Two inference servers on one ServeSet must produce predictions
/// bit-identical to standalone single-system servers, while a mixed
/// power flood runs through the shared batcher.
#[test]
fn shared_serveset_inference_matches_single_system_baseline() {
    if !artifacts_ready() {
        return;
    }
    let systems = ["pendulum", "beam"];
    let set = ServeSet::boot(&systems, FlowConfig::default(), None).unwrap();
    let batcher = set.power_batcher(Duration::from_micros(200), 2);
    let mut flood = Vec::new();
    for system in systems {
        let trained =
            train::run_training("artifacts", system, FeatureKind::Pi, 400, 0x1E57).unwrap();
        let config = |sys: &str| ServerConfig {
            artifacts: "artifacts".into(),
            system: sys.into(),
            max_batch: 32,
            linger: Duration::from_micros(200),
            pi_path: PiPath::Native,
        };
        let shared =
            InferenceServer::start_shared(config(system), trained.clone(), set.handle(system).unwrap())
                .unwrap();
        let solo = InferenceServer::start(config(system), trained.clone()).unwrap();

        let export = trained.dataset.export.clone();
        let mut rng = Lfsr32::new(0xE2E2);
        for i in 0..48 {
            let s = stim::sample(system, &mut rng).unwrap();
            let values_q: Vec<i64> =
                export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
            let a = shared
                .submit(SensorInput { values_q: values_q.clone() })
                .recv()
                .unwrap()
                .unwrap();
            let b = solo.submit(SensorInput { values_q }).recv().unwrap().unwrap();
            assert_eq!(a.pis, b.pis, "{system} sample {i}: Π mismatch");
            assert_eq!(a.pi0_pred.to_bits(), b.pi0_pred.to_bits(), "{system} sample {i}");
            assert_eq!(
                a.target_estimate.to_bits(),
                b.target_estimate.to_bits(),
                "{system} sample {i}"
            );
            // Interleave power requests with the inference stream.
            let sys_index = set.system_index(system).unwrap();
            flood.push(batcher.submit(
                sys_index,
                PowerRequest { seed: 0xAB00 + i as u32, f_hz: 6.0e6 },
            ));
        }
        let shared_stats = shared.shutdown();
        let solo_stats = solo.shutdown();
        assert_eq!(shared_stats.samples, 48);
        assert_eq!(solo_stats.samples, 48);
        assert!(!shared_stats.worker_panicked);
    }
    for rx in flood {
        assert!(rx.recv().unwrap().unwrap().mw > 0.0);
    }
    let stats = batcher.shutdown();
    assert_eq!(stats.requests, 96, "{stats:?}");
}
