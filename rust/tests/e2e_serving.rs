//! End-to-end serving tests: train → serve → verify online accuracy and
//! coordinator behaviour (batching, concurrency, shutdown).

use dimsynth::coordinator::{
    serve_synthetic, InferenceServer, PiPath, SensorInput, ServerConfig,
};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::stim::{self, Lfsr32};
use dimsynth::train::{self, FeatureKind};
use std::time::Duration;

fn artifacts_ready() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn start_server(system: &str, pi_path: PiPath) -> (InferenceServer, train::TrainOutput) {
    let trained =
        train::run_training("artifacts", system, FeatureKind::Pi, 400, 0x1E57).unwrap();
    let server = InferenceServer::start(
        ServerConfig {
            artifacts: "artifacts".into(),
            system: system.into(),
            max_batch: 32,
            linger: Duration::from_micros(200),
            pi_path,
        },
        trained.clone(),
    )
    .unwrap();
    (server, trained)
}

#[test]
fn serve_synthetic_reports() {
    if !artifacts_ready() {
        return;
    }
    let report = serve_synthetic("artifacts", "pendulum", 256, 32).unwrap();
    assert!(report.contains("throughput"), "{report}");
    assert!(report.contains("pendulum"));
}

#[test]
fn online_accuracy_beam() {
    if !artifacts_ready() {
        return;
    }
    let (server, trained) = start_server("beam", PiPath::Native);
    let export = trained.dataset.export.clone();
    let mut rng = Lfsr32::new(0xE2E);
    let mut pending = Vec::new();
    let mut truths = Vec::new();
    for _ in 0..300 {
        let s = stim::sample("beam", &mut rng).unwrap();
        truths.push(s[export.target_index]);
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut rel = 0f64;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let p = rx.recv().unwrap().unwrap();
        assert!(p.target_estimate.is_finite());
        rel += ((p.target_estimate - truth) / truth).abs();
    }
    let stats = server.shutdown();
    assert_eq!(stats.samples, 300);
    let mean_rel = rel / 300.0;
    assert!(mean_rel < 0.15, "beam online error {mean_rel}");
}

#[test]
fn rtl_sim_path_serves_and_reports_cycles() {
    if !artifacts_ready() {
        return;
    }
    let (server, trained) = start_server("pendulum", PiPath::RtlSim);
    let export = trained.dataset.export.clone();
    let mut rng = Lfsr32::new(0x515);
    let mut pending = Vec::new();
    for _ in 0..32 {
        let s = stim::sample("pendulum", &mut rng).unwrap();
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    for rx in pending {
        let p = rx.recv().unwrap().unwrap();
        // The pendulum module takes 115 cycles per sample.
        assert_eq!(p.hw_cycles, Some(115));
    }
    server.shutdown();
}

#[test]
fn hlo_pi_path_agrees_with_native_in_serving() {
    if !artifacts_ready() {
        return;
    }
    let (native, trained_a) = start_server("unpowered_flight", PiPath::Native);
    let (hlo, trained_b) = start_server("unpowered_flight", PiPath::Hlo);
    // Identical training seeds → identical parameters.
    assert_eq!(trained_a.params, trained_b.params);
    let export = trained_a.dataset.export.clone();
    let mut rng = Lfsr32::new(0x777);
    for _ in 0..16 {
        let s = stim::sample("unpowered_flight", &mut rng).unwrap();
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        let pa = native
            .submit(SensorInput { values_q: values_q.clone() })
            .recv()
            .unwrap()
            .unwrap();
        let pb = hlo.submit(SensorInput { values_q }).recv().unwrap().unwrap();
        assert_eq!(pa.pis, pb.pis, "Π mismatch between native and HLO paths");
        assert!((pa.pi0_pred - pb.pi0_pred).abs() < 1e-5);
    }
    native.shutdown();
    hlo.shutdown();
}

#[test]
fn concurrent_submitters() {
    if !artifacts_ready() {
        return;
    }
    let (server, trained) = start_server("spring_mass", PiPath::Native);
    let export = trained.dataset.export.clone();
    let server = std::sync::Arc::new(server);
    let mut joins = Vec::new();
    for t in 0..4u32 {
        let server = server.clone();
        let export = export.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Lfsr32::new(0x100 + t);
            let mut ok = 0usize;
            for _ in 0..64 {
                let s = stim::sample("spring_mass", &mut rng).unwrap();
                let values_q: Vec<i64> =
                    export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
                let p = server.submit(SensorInput { values_q }).recv().unwrap().unwrap();
                if p.target_estimate.is_finite() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 4 * 64);
    let stats = std::sync::Arc::try_unwrap(server)
        .ok()
        .expect("all submitters done")
        .shutdown();
    assert_eq!(stats.samples, 256);
    assert!(stats.batches >= 8, "batching too coarse: {}", stats.batches);
}

#[test]
fn unknown_system_fails_cleanly() {
    if !artifacts_ready() {
        return;
    }
    let err = serve_synthetic("artifacts", "warp_core", 8, 4).unwrap_err().to_string();
    assert!(err.contains("warp_core"), "{err}");
}
