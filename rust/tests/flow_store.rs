//! Persistence semantics of the flow artifact store: warm starts across
//! store re-opens and across real processes must reproduce every artifact
//! bit-identically with zero stage recomputes, and corrupt entries must
//! degrade to clean recomputes.

use dimsynth::flow::{ArtifactStore, Flow, FlowConfig, FlowSet};
use std::path::PathBuf;
use std::sync::Arc;

fn temp_store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dimsynth-flowstore-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_config() -> FlowConfig {
    FlowConfig { power_samples: 2, ..FlowConfig::default() }
}

/// Bit-exact summary of every stage of one session (f64s compared by
/// bit pattern, Verilog by full text).
type Row = (String, usize, usize, u32, u64, u64, u64, u64, String);

fn summarize(f: &mut Flow) -> Row {
    let (cells, gates) = {
        let m = f.netlist().unwrap();
        (m.lut4_cells, m.gate_count)
    };
    let t = f.timing().unwrap();
    let p = f.power().unwrap();
    (
        f.id().to_string(),
        cells,
        gates,
        t.depth,
        t.fmax_mhz.to_bits(),
        p.mw_6mhz.to_bits(),
        p.activity.toggles_per_cycle.to_bits(),
        f.latency().unwrap(),
        f.verilog().unwrap().to_string(),
    )
}

#[test]
fn warm_start_reproduces_corpus_with_zero_recomputes() {
    let dir = temp_store_dir("warm");

    // Cold run: compute everything, populating the store.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut cold = FlowSet::corpus(small_config()).with_store(store);
    let cold_rows: Vec<Row> = cold.run_sequential(summarize);
    let cold_counts = cold.total_counts();
    assert_eq!(cold_counts.recomputes(), 7 * 7, "cold run computes all 7 stages x 7 systems");
    assert_eq!(cold_counts.disk_hits, 0);
    drop(cold);

    // Warm start: fresh sessions against a re-opened store — exactly
    // what a second process sees. Nothing may recompute, and every
    // artifact must be bit-identical.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut warm = FlowSet::corpus(small_config()).with_store(store);
    let warm_rows: Vec<Row> = warm.run_sequential(summarize);
    let counts = warm.total_counts();
    assert_eq!(counts.recomputes(), 0, "warm start must serve every stage from disk: {counts:?}");
    // Lazy materialization: only the 5 stages `summarize` actually
    // queries (netlist, timing, power, rtl via latency, verilog) load —
    // parse and Π artifacts stay on disk untouched.
    assert_eq!(counts.disk_hits, 5 * 7, "queried stages x 7 systems from disk: {counts:?}");
    assert_eq!(cold_rows, warm_rows, "artifacts must be bit-identical across processes");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_single_stage_query_loads_exactly_one_artifact() {
    let dir = temp_store_dir("lazy");
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut cold =
        Flow::for_system("pendulum", small_config()).unwrap().with_store(Arc::clone(&store));
    let t_cold = cold.timing().unwrap();
    let p_cold = cold.power().unwrap();
    drop(cold);

    // The fingerprint chain needs only config + source, so a warm
    // timing query must deserialize the timing artifact and nothing
    // upstream of it.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut warm =
        Flow::for_system("pendulum", small_config()).unwrap().with_store(store);
    let t = warm.timing().unwrap();
    assert_eq!(t.fmax_mhz.to_bits(), t_cold.fmax_mhz.to_bits());
    let c = warm.counts();
    assert_eq!(c.recomputes(), 0, "{c:?}");
    assert_eq!(c.disk_hits, 1, "warm timing query must load exactly one artifact: {c:?}");

    // A power query on the same session adds exactly one more load.
    let p = warm.power().unwrap();
    assert_eq!(p.mw_6mhz.to_bits(), p_cold.mw_6mhz.to_bits());
    let c = warm.counts();
    assert_eq!((c.recomputes(), c.disk_hits), (0, 2), "{c:?}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parallel_driver_shares_the_store_safely() {
    let dir = temp_store_dir("parallel");

    // Populate concurrently (concurrent writers, atomic renames)...
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut cold = FlowSet::corpus(small_config()).with_store(store);
    let cold_rows: Vec<Row> = cold.run_parallel(summarize);
    drop(cold);

    // ...then a warm parallel run must be hit-only and identical.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut warm = FlowSet::corpus(small_config()).with_store(store);
    let warm_rows: Vec<Row> = warm.run_parallel(summarize);
    assert_eq!(warm.total_counts().recomputes(), 0);
    assert_eq!(cold_rows, warm_rows);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_entries_recompute_gracefully() {
    let dir = temp_store_dir("corrupt");
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut flow =
        Flow::for_system("pendulum", small_config()).unwrap().with_store(Arc::clone(&store));
    let cells = flow.netlist().unwrap().lut4_cells;
    let verilog = flow.verilog().unwrap().to_string();
    drop(flow);

    // Truncate every netlist entry; flip a byte in every verilog entry.
    let mut mangled = 0;
    for (stage, truncate) in [("netlist", true), ("verilog", false)] {
        for de in std::fs::read_dir(dir.join(stage)).unwrap().flatten() {
            let path = de.path();
            let bytes = std::fs::read(&path).unwrap();
            if truncate {
                std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
            } else {
                let mut b = bytes;
                let mid = b.len() / 2;
                b[mid] ^= 0xFF;
                std::fs::write(&path, &b).unwrap();
            }
            mangled += 1;
        }
    }
    assert!(mangled >= 2, "expected stored netlist and verilog entries");

    // A fresh session must detect the damage, recompute those two
    // stages, and still serve the intact upstream stages from disk.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut flow = Flow::for_system("pendulum", small_config()).unwrap().with_store(store);
    assert_eq!(flow.netlist().unwrap().lut4_cells, cells);
    assert_eq!(flow.verilog().unwrap(), verilog);
    let c = flow.counts();
    assert_eq!((c.netlist, c.verilog), (1, 1), "corrupt entries must recompute: {c:?}");
    assert_eq!((c.parsed, c.pis, c.rtl), (0, 0, 0), "intact entries must come from disk: {c:?}");

    // The recompute healed the store: one more re-open is hit-only.
    let healed = Arc::new(ArtifactStore::open(&dir).unwrap());
    let mut again = Flow::for_system("pendulum", small_config()).unwrap().with_store(healed);
    again.netlist().unwrap();
    again.verilog().unwrap();
    assert_eq!(again.counts().recomputes(), 0, "{:?}", again.counts());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cross_process_warm_start_via_cli() {
    let dir = temp_store_dir("xproc");
    let exe = env!("CARGO_BIN_EXE_dimsynth");
    let run = |label: &str| {
        let out = std::process::Command::new(exe)
            .args(["compile", "pendulum", "--cache-dir"])
            .arg(&dir)
            .output()
            .expect("spawn dimsynth");
        assert!(
            out.status.success(),
            "{label} run failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        (
            String::from_utf8_lossy(&out.stdout).to_string(),
            String::from_utf8_lossy(&out.stderr).to_string(),
        )
    };

    let (cold_out, cold_err) = run("cold");
    let (warm_out, warm_err) = run("warm");
    assert_eq!(cold_out, warm_out, "stdout reports must be identical across processes");
    assert!(cold_err.contains("cache: recomputes="), "missing cache line: {cold_err}");
    assert!(
        warm_err.contains("recomputes=0 "),
        "second process must recompute nothing: {warm_err}"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
