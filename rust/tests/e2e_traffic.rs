//! Fault-injection e2e for the network serving layer: four tenants with
//! hostile traffic shapes run against one real TCP front end
//! (`coordinator::net`) over one warm [`ServeSet`], deterministically
//! seeded:
//!
//! - `good`   — well-behaved stream; one injected worker panic (seq 17).
//! - `flood`  — rate-limited far below its offered load; must be shed
//!   with typed `Shed` refusals, never hangs.
//! - `slow`   — every request gets an injected 3 ms compute delay but
//!   carries a 1 ms deadline; must be dropped as `DeadlineExceeded`
//!   without burning compute on dead work.
//! - `flaky`  — drops its connection mid-stream with a window of
//!   requests still in flight.
//!
//! The contract under all of that, checked from both sides of the wire:
//! every request the client sent gets exactly one typed response (served
//! / shed / deadline-exceeded / worker-panicked) unless the client
//! itself hung up; the well-behaved tenant's p99 stays bounded; the
//! engine survives the panic and the disconnects; and graceful drain
//! leaves zero admitted requests unanswered (`terminal == admitted`,
//! empty queues) — no hangs, no silent drops.
//!
//! The hostile mix runs on **two dispatch lanes** so every invariant
//! above is exercised under hash-sharded multi-lane dispatch, and the
//! lane-topology tests below pin tenants to lanes explicitly: a flooder
//! and a light tenant must coexist fairly whether they share a lane
//! (round-robin within the lane) or sit on different lanes (isolation),
//! and a lane killed by an injected uncontained dispatcher panic must
//! be swept at drain with typed answers while the other lane keeps
//! serving live.

use dimsynth::coordinator::net::run_driver;
use dimsynth::coordinator::{
    AdmissionConfig, DriverConfig, DriverReport, EngineConfig, FaultPlan, NetClient,
    NetServer, ServeError, ServeSet, TenantSpec, TrafficEngine, TrafficReport,
};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::FlowConfig;
use dimsynth::synth::LaneWidth;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[test]
fn hostile_traffic_mix_is_fully_answered_and_contained() {
    let config = FlowConfig {
        power_samples: 2,
        lane_width: LaneWidth::W64,
        ..FlowConfig::default()
    };
    let set = ServeSet::boot(&["pendulum", "spring_mass"], config, None).unwrap();
    let pendulum_ports = set.handle_at(0).design().num_inputs();
    let spring_ports = set.handle_at(1).design().num_inputs();

    let admission = AdmissionConfig {
        tenants: vec![
            TenantSpec::new("good", "pendulum").with_queue_cap(4096),
            // Far below the flood's offered load: most of it must shed.
            TenantSpec::new("flood", "spring_mass")
                .with_rate(200.0, 8.0)
                .with_queue_cap(32),
            TenantSpec::new("slow", "spring_mass").with_queue_cap(4096),
            TenantSpec::new("flaky", "pendulum").with_queue_cap(4096),
        ],
        default_deadline: Duration::from_secs(10),
    };
    // Deterministic faults, keyed on (tenant, admission seq): tenant
    // `good`'s 18th admitted request panics inside the worker; every
    // `slow` request is delayed past its own deadline.
    let faults = FaultPlan::none()
        .panic_at("good", 17)
        .delay_all("slow", Duration::from_millis(3));

    let engine = Arc::new(
        TrafficEngine::start(
            &set,
            admission,
            EngineConfig { activations: 2, max_batch: 16, dispatchers: 2 },
            faults,
        )
        .unwrap(),
    );
    let server = NetServer::start(engine, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // Generous wire deadlines for the tenants whose outcome should be
    // decided by admission, not the clock — only `slow` carries the
    // deliberately impossible 1 ms budget.
    let drivers = vec![
        DriverConfig {
            requests: 120,
            window: 8,
            seed: 0x600D,
            deadline_us: 10_000_000,
            ..DriverConfig::new("good", pendulum_ports)
        },
        DriverConfig {
            requests: 200,
            window: 16,
            seed: 0xF100D,
            deadline_us: 10_000_000,
            ..DriverConfig::new("flood", spring_ports)
        },
        DriverConfig {
            requests: 40,
            window: 8,
            seed: 0x510,
            deadline_us: 1_000,
            ..DriverConfig::new("slow", spring_ports)
        },
        DriverConfig {
            requests: 60,
            window: 16,
            seed: 0xF1A2,
            disconnect_after_reads: Some(10),
            ..DriverConfig::new("flaky", pendulum_ports)
        },
    ];
    let joins: Vec<_> = drivers
        .into_iter()
        .map(|cfg| {
            let addr = addr.clone();
            std::thread::spawn(move || (cfg.tenant.clone(), run_driver(&addr, &cfg).unwrap()))
        })
        .collect();
    let mut reports = std::collections::HashMap::<String, DriverReport>::new();
    for j in joins {
        let (tenant, report) = j.join().unwrap();
        reports.insert(tenant, report);
    }

    // -- client side: exactly one typed response per request ------------
    let good = &reports["good"];
    assert_eq!(good.sent, 120);
    assert_eq!(good.answered(), good.sent, "{good:?}");
    assert_eq!(good.panicked, 1, "exactly the injected panic: {good:?}");
    assert_eq!(good.ok, good.sent - 1, "{good:?}");
    // Bounded tail for the well-behaved tenant despite flood + slow +
    // panic sharing the server (its own deadline allowed 10 s).
    let p99 = good.latency.percentile_us(0.99);
    assert!(p99 < 2_000_000, "good p99 {p99} µs not bounded");

    let flood = &reports["flood"];
    assert_eq!(flood.sent, 200);
    assert_eq!(flood.answered(), flood.sent, "no hangs, no silent drops: {flood:?}");
    assert!(flood.shed > 0, "rate limit must shed: {flood:?}");
    assert!(flood.ok >= 1, "burst capacity must admit some: {flood:?}");
    assert_eq!(flood.ok + flood.shed + flood.deadline_exceeded, flood.sent, "{flood:?}");

    let slow = &reports["slow"];
    assert_eq!(slow.sent, 40);
    assert_eq!(slow.answered(), slow.sent, "{slow:?}");
    assert_eq!(slow.ok, 0, "3 ms injected delay > 1 ms budget: {slow:?}");
    assert!(slow.deadline_exceeded > 0, "{slow:?}");

    let flaky = &reports["flaky"];
    assert!(flaky.disconnected, "driver must have hung up mid-stream");
    assert!(flaky.sent > flaky.answered(), "disconnect left work in flight: {flaky:?}");

    // -- server side: graceful drain, nothing admitted goes unanswered --
    let report = server.shutdown();
    assert!(!report.engine_panicked, "injected panic must be contained");
    for t in &report.tenants {
        assert_eq!(
            t.counters.terminal(),
            t.counters.admitted,
            "tenant `{}` drain left work unanswered: {:?}",
            t.tenant,
            t.counters
        );
        assert_eq!(t.queue_depth, 0, "tenant `{}` queue not drained", t.tenant);
        assert_eq!(t.queue_oldest_ms, 0, "tenant `{}` queue not drained", t.tenant);
    }
    let g = &report.tenant("good").unwrap().counters;
    assert_eq!(g.panicked, 1, "{g:?}");
    assert_eq!(g.served + 1, g.admitted, "{g:?}");
    let f = &report.tenant("flood").unwrap().counters;
    assert!(f.shed > 0, "{f:?}");
    let s = &report.tenant("slow").unwrap().counters;
    assert!(s.deadline_expired > 0, "{s:?}");
    // The flaky client's in-flight work was still answered; the server
    // noticed the dead connection (reader error or undeliverable write).
    let fl = &report.tenant("flaky").unwrap().counters;
    assert_eq!(fl.terminal(), fl.admitted, "{fl:?}");
    assert!(
        report.disconnects >= 1 || report.undelivered >= 1,
        "server must notice the mid-stream disconnect: {report}"
    );
    let totals = report.totals();
    assert_eq!(totals.terminal(), totals.admitted, "global drain invariant: {totals:?}");
}

/// Boot a two-lane engine with a flooding tenant pinned to lane 0 and a
/// light tenant pinned to `light_lane`, run both driver shapes
/// concurrently against the TCP front end, and return (light report,
/// flooder report, drained server report).
fn run_flood_vs_light(light_lane: usize) -> (DriverReport, DriverReport, TrafficReport) {
    let config = FlowConfig {
        power_samples: 2,
        lane_width: LaneWidth::W64,
        ..FlowConfig::default()
    };
    let set = ServeSet::boot(&["pendulum"], config, None).unwrap();
    let ports = set.handle_at(0).design().num_inputs();

    // No rate limit on the flooder: the pressure it exerts is real
    // queued compute, so any fairness the light tenant sees comes from
    // the dispatcher's per-lane round-robin, not from admission shed.
    let admission = AdmissionConfig {
        tenants: vec![
            TenantSpec::new("flooder", "pendulum").with_queue_cap(4096).with_lane(0),
            TenantSpec::new("light", "pendulum").with_queue_cap(4096).with_lane(light_lane),
        ],
        default_deadline: Duration::from_secs(30),
    };
    let engine = Arc::new(
        TrafficEngine::start(
            &set,
            admission,
            EngineConfig { activations: 2, max_batch: 16, dispatchers: 2 },
            FaultPlan::none(),
        )
        .unwrap(),
    );
    let server = NetServer::start(engine, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let drivers = vec![
        DriverConfig {
            requests: 300,
            window: 32,
            seed: 0xF100D,
            deadline_us: 20_000_000,
            ..DriverConfig::new("flooder", ports)
        },
        DriverConfig {
            requests: 40,
            window: 2,
            seed: 0x116_87,
            deadline_us: 20_000_000,
            ..DriverConfig::new("light", ports)
        },
    ];
    let joins: Vec<_> = drivers
        .into_iter()
        .map(|cfg| {
            let addr = addr.clone();
            std::thread::spawn(move || (cfg.tenant.clone(), run_driver(&addr, &cfg).unwrap()))
        })
        .collect();
    let mut reports = std::collections::HashMap::<String, DriverReport>::new();
    for j in joins {
        let (tenant, report) = j.join().unwrap();
        reports.insert(tenant, report);
    }
    let server_report = server.shutdown();
    (reports.remove("light").unwrap(), reports.remove("flooder").unwrap(), server_report)
}

/// Shared assertions for both lane placements: the light tenant is
/// never starved, never shed, and keeps a bounded tail; the flooder is
/// fully answered; drain leaves nothing unanswered on either lane.
fn assert_flood_vs_light(light: &DriverReport, flooder: &DriverReport, report: &TrafficReport) {
    assert_eq!(light.sent, 40);
    assert_eq!(light.answered(), light.sent, "{light:?}");
    assert_eq!(light.ok, light.sent, "zero starvation for the light tenant: {light:?}");
    let p99 = light.latency.percentile_us(0.99);
    assert!(p99 < 2_000_000, "light p99 {p99} µs not bounded next to a flooder");

    assert_eq!(flooder.sent, 300);
    assert_eq!(flooder.answered(), flooder.sent, "{flooder:?}");

    assert!(!report.engine_panicked);
    assert_eq!(report.lanes.len(), 2, "{report}");
    for t in &report.tenants {
        assert_eq!(t.counters.terminal(), t.counters.admitted, "tenant `{}`", t.tenant);
        assert_eq!(t.queue_depth, 0, "tenant `{}` queue not drained", t.tenant);
    }
    assert_eq!(report.tenant("light").unwrap().counters.served, 40);
}

#[test]
fn light_tenant_is_fairly_served_sharing_a_lane_with_a_flooder() {
    let (light, flooder, report) = run_flood_vs_light(0);
    // Both tenants really landed on lane 0; lane 1 idled.
    let lane0 = &report.lanes[0];
    assert_eq!(lane0.tenants, vec!["flooder".to_string(), "light".to_string()], "{report}");
    assert_eq!(report.lanes[1].items, 0, "pinning must leave lane 1 empty: {report}");
    assert_flood_vs_light(&light, &flooder, &report);
}

#[test]
fn light_tenant_is_isolated_from_a_flooder_on_another_lane() {
    let (light, flooder, report) = run_flood_vs_light(1);
    assert_eq!(report.lanes[0].tenants, vec!["flooder".to_string()], "{report}");
    assert_eq!(report.lanes[1].tenants, vec!["light".to_string()], "{report}");
    assert!(report.lanes[1].items >= 40, "light's lane must have carried its work: {report}");
    assert_flood_vs_light(&light, &flooder, &report);
}

#[test]
fn killed_lane_drains_typed_over_tcp_while_other_lane_serves_live() {
    let config = FlowConfig {
        power_samples: 2,
        lane_width: LaneWidth::W64,
        ..FlowConfig::default()
    };
    let set = ServeSet::boot(&["pendulum"], config, None).unwrap();
    let ports = set.handle_at(0).design().num_inputs();

    let admission = AdmissionConfig {
        tenants: vec![
            TenantSpec::new("doomed", "pendulum").with_queue_cap(4096).with_lane(0),
            TenantSpec::new("steady", "pendulum").with_queue_cap(4096).with_lane(1),
        ],
        default_deadline: Duration::from_secs(30),
    };
    // Lane 0's dispatcher dies uncontained on its very first batch.
    let faults = FaultPlan::none().kill_lane_at(0, 0);
    let engine = Arc::new(
        TrafficEngine::start(
            &set,
            admission,
            EngineConfig { activations: 2, max_batch: 16, dispatchers: 2 },
            faults,
        )
        .unwrap(),
    );
    let server = NetServer::start(engine.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // The doomed client sends its whole window up front, then blocks
    // reading: its answers can only arrive from the drain-time lane
    // sweep, typed WorkerPanicked naming the dead lane.
    const DOOMED: u32 = 6;
    let doomed_addr = addr.clone();
    let doomed = std::thread::spawn(move || {
        let mut client = NetClient::connect(&doomed_addr).unwrap();
        let values: Vec<i64> = vec![Q16_15.from_f64(1.0); ports];
        for i in 0..DOOMED {
            client.send_pi(i, "doomed", 0, &values).unwrap();
        }
        let mut panicked = 0;
        for _ in 0..DOOMED {
            let resp = client.recv().unwrap();
            match resp.result.unwrap_err() {
                ServeError::WorkerPanicked { reason } => {
                    assert!(reason.contains("lane 0"), "{reason}");
                    panicked += 1;
                }
                other => panic!("expected WorkerPanicked, got {other}"),
            }
        }
        panicked
    });

    // Wait until every doomed frame is admitted (queued on the dead
    // lane), so the drain sweep — not a racing dispatcher — answers it.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let admitted =
            engine.report().tenant("doomed").map(|t| t.counters.admitted).unwrap_or(0);
        if admitted == DOOMED as u64 {
            break;
        }
        assert!(Instant::now() < deadline, "doomed frames never admitted");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Lane 1 keeps serving live while lane 0 is dead.
    let steady = run_driver(
        &addr,
        &DriverConfig {
            requests: 30,
            window: 4,
            seed: 0x57EAD,
            deadline_us: 20_000_000,
            ..DriverConfig::new("steady", ports)
        },
    )
    .unwrap();
    assert_eq!(steady.ok, 30, "live lane must be undisturbed: {steady:?}");

    let report = server.shutdown();
    assert_eq!(doomed.join().unwrap(), DOOMED, "every doomed request answered typed");

    assert!(report.engine_panicked, "the lane kill must be visible in the report");
    assert!(report.lanes[0].panicked, "{report}");
    assert!(!report.lanes[1].panicked, "{report}");
    let d = &report.tenant("doomed").unwrap().counters;
    assert_eq!(d.panicked, DOOMED as u64, "{d:?}");
    assert_eq!(d.terminal(), d.admitted, "{d:?}");
    let s = &report.tenant("steady").unwrap().counters;
    assert_eq!(s.served, 30, "{s:?}");
    for t in &report.tenants {
        assert_eq!(t.queue_depth, 0, "tenant `{}` queue not drained", t.tenant);
    }
}
