//! Memoization semantics of the [`Flow`] compilation-session API:
//! per-stage recompute counts, downstream-only invalidation on config
//! change, and parallel/sequential equivalence of the [`FlowSet`]
//! corpus driver.

use dimsynth::fixedpoint::QFormat;
use dimsynth::flow::{Flow, FlowConfig, FlowSet, StageCounts};
use dimsynth::synth::LaneWidth;

fn small_config() -> FlowConfig {
    FlowConfig { power_samples: 2, ..FlowConfig::default() }
}

/// Changing the lane width re-measures only the power stage, reshapes
/// its spread (256-lane default → 64 lanes), and leaves the headline
/// figures — lane 0 carries the same `power_seed` stream at either
/// width — bit-identical.
#[test]
fn lane_width_shapes_power_spread_but_not_headline_figures() {
    let mut flow = Flow::for_system("pendulum", small_config()).unwrap();
    let p256 = flow.power().unwrap();
    assert_eq!(p256.spread.lanes, 256, "default width is 256 lanes");
    assert!(p256.spread.min_tpc <= p256.spread.mean_tpc);
    assert!(p256.spread.mean_tpc <= p256.spread.max_tpc);

    flow.set_lane_width(LaneWidth::W64);
    let p64 = flow.power().unwrap();
    assert_eq!(p64.spread.lanes, 64);
    assert_eq!(p64.activity.toggles_per_cycle, p256.activity.toggles_per_cycle);
    assert_eq!(p64.activity.cycles, p256.activity.cycles);
    assert_eq!(p64.mw_6mhz, p256.mw_6mhz);
    assert_eq!(p64.mw_12mhz, p256.mw_12mhz);

    let c = flow.counts();
    assert_eq!(c.power, 2, "width change must re-measure power: {c:?}");
    assert_eq!(
        (c.parsed, c.pis, c.rtl, c.netlist, c.timing),
        (1, 1, 1, 1, 0),
        "width change must not invalidate upstream stages: {c:?}"
    );

    // Return trip: the 256-lane artifact is still in the stage LRU.
    flow.set_lane_width(LaneWidth::W256);
    let back = flow.power().unwrap();
    assert_eq!(back.spread.lanes, 256);
    assert_eq!(flow.counts().power, 2, "return trip must hit the LRU");
}

#[test]
fn every_stage_computes_once_across_repeated_queries() {
    let mut flow = Flow::for_system("pendulum", small_config()).unwrap();
    // Query the deepest stage repeatedly: the whole upstream chain must
    // compute exactly once.
    let first = flow.power().unwrap();
    let again = flow.power().unwrap();
    assert_eq!(first.mw_6mhz, again.mw_6mhz);
    assert_eq!(first.activity.cycles, again.activity.cycles);

    // Re-query every stage; nothing recomputes.
    flow.parsed().unwrap();
    flow.pis().unwrap();
    flow.rtl().unwrap();
    flow.netlist().unwrap();
    flow.timing().unwrap();
    flow.power().unwrap();
    flow.verilog().unwrap();
    flow.latency().unwrap();

    let c = flow.counts();
    assert_eq!(
        c,
        StageCounts {
            parsed: 1,
            pis: 1,
            rtl: 1,
            netlist: 1,
            timing: 1,
            power: 1,
            verilog: 1,
            ..StageCounts::default()
        },
        "each stage must compute exactly once, with no hits counted"
    );
}

#[test]
fn qformat_change_invalidates_rtl_downstream_but_not_parse_or_pis() {
    let mut flow = Flow::for_system("pendulum", small_config()).unwrap();
    flow.timing().unwrap();
    flow.power().unwrap();
    let before = flow.counts();

    flow.set_qformat(QFormat::new(12, 11));
    flow.timing().unwrap();
    flow.power().unwrap();
    let after = flow.counts();

    assert_eq!(after.parsed, before.parsed, "parse must stay cached");
    assert_eq!(after.pis, before.pis, "Π-search must stay cached");
    assert_eq!(after.rtl, before.rtl + 1, "RTL must rebuild");
    assert_eq!(after.netlist, before.netlist + 1, "netlist must remap");
    assert_eq!(after.timing, before.timing + 1, "timing must rerun");
    assert_eq!(after.power, before.power + 1, "power must remeasure");
}

#[test]
fn power_stimulus_change_invalidates_only_the_power_stage() {
    let mut flow = Flow::for_system("pendulum", small_config()).unwrap();
    flow.timing().unwrap();
    let p1 = flow.power().unwrap();
    let before = flow.counts();

    flow.set_power_stimulus(2, 0xBEEF);
    let p2 = flow.power().unwrap();
    flow.timing().unwrap();
    let after = flow.counts();

    assert_eq!(after.parsed, before.parsed);
    assert_eq!(after.pis, before.pis);
    assert_eq!(after.rtl, before.rtl);
    assert_eq!(after.netlist, before.netlist);
    assert_eq!(after.timing, before.timing, "timing does not depend on stimulus");
    assert_eq!(after.power, before.power + 1);
    // Different seed → different measured activity (overwhelmingly).
    assert_ne!(p1.activity.toggles_per_cycle, p2.activity.toggles_per_cycle);
}

#[test]
fn cached_results_match_fresh_sessions_after_invalidation() {
    // A session that sweeps away from a config and back must agree with
    // a fresh session at the final config (the return trip is served by
    // the per-stage LRU, bit-exactly).
    let mut swept = Flow::for_system("beam", small_config()).unwrap();
    let cells_q16 = swept.netlist().unwrap().lut4_cells;
    swept.set_qformat(QFormat::new(8, 7));
    let cells_q8 = swept.netlist().unwrap().lut4_cells;
    assert!(cells_q8 < cells_q16);
    swept.set_qformat(QFormat::new(16, 15));
    assert_eq!(swept.netlist().unwrap().lut4_cells, cells_q16);

    let mut fresh = Flow::for_system("beam", small_config()).unwrap();
    assert_eq!(fresh.netlist().unwrap().lut4_cells, cells_q16);
}

#[test]
fn sweep_return_trips_hit_the_per_stage_lru() {
    let mut flow = Flow::for_system("pendulum", small_config()).unwrap();
    let cells_q16 = flow.netlist().unwrap().lut4_cells;
    let fmax_q16 = flow.timing().unwrap().fmax_mhz;

    flow.set_qformat(QFormat::new(12, 11));
    flow.netlist().unwrap();
    flow.timing().unwrap();
    let mid = flow.counts();
    assert_eq!(mid.rtl, 2, "second format must rebuild RTL once");

    // Return trip: every revisited stage must come from the in-memory
    // LRU — zero recomputes, bit-identical results.
    flow.set_qformat(QFormat::new(16, 15));
    assert_eq!(flow.netlist().unwrap().lut4_cells, cells_q16);
    assert_eq!(flow.timing().unwrap().fmax_mhz.to_bits(), fmax_q16.to_bits());
    let after = flow.counts();
    assert_eq!(
        (after.parsed, after.pis, after.rtl, after.netlist, after.timing),
        (mid.parsed, mid.pis, mid.rtl, mid.netlist, mid.timing),
        "return trip must not recompute any stage"
    );
    assert!(
        after.memory_hits > mid.memory_hits,
        "return trip must be served by LRU promotion ({} -> {})",
        mid.memory_hits,
        after.memory_hits
    );
}

#[test]
fn flowset_parallel_results_are_identical_to_sequential() {
    type Row = (String, usize, usize, u64, f64, f64, u32);
    let summarize = |f: &mut Flow| -> Row {
        let (cells, gates) = {
            let m = f.netlist().unwrap();
            (m.lut4_cells, m.gate_count)
        };
        let timing = f.timing().unwrap();
        let power = f.power().unwrap();
        (
            f.id().to_string(),
            cells,
            gates,
            f.latency().unwrap(),
            timing.fmax_mhz,
            power.activity.toggles_per_cycle,
            power.activity.activations,
        )
    };
    let sequential: Vec<Row> = FlowSet::corpus(small_config()).run_sequential(summarize);
    let parallel: Vec<Row> = FlowSet::corpus(small_config()).run_parallel(summarize);
    assert_eq!(sequential.len(), 7);
    assert_eq!(sequential, parallel, "parallel corpus run must be bit-identical");
}
