//! Minimal in-tree shim of the `anyhow` error-handling API.
//!
//! Provides exactly the subset this repository uses — [`Result`],
//! [`Error`], [`anyhow!`], [`bail!`], and a blanket `From` for standard
//! error types — with no external dependencies. The real crate is a
//! drop-in replacement (see `rust/vendor/README.md`).

use std::fmt;

/// A string-backed error value (the shim drops `anyhow`'s source chain;
/// the chain is flattened into the message at conversion time).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Flatten a standard error (and its source chain) into an [`Error`].
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string unless the
/// condition holds (the message-carrying subset of `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn display_and_debug_are_the_message() {
        let e = crate::anyhow!("thing {} failed", 7);
        assert_eq!(e.to_string(), "thing 7 failed");
        assert_eq!(format!("{e:?}"), "thing 7 failed");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("x").is_err());
    }

    #[test]
    fn ensure_checks_condition() {
        fn f(v: u32) -> crate::Result<u32> {
            crate::ensure!(v < 10, "too big: {v}");
            Ok(v)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> crate::Result<u32> {
            if flag {
                crate::bail!("flagged: {flag}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged: true");
    }
}
