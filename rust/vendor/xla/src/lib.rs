//! In-tree stub of the PJRT/XLA binding surface used by
//! [`dimsynth`](../../../src/lib.rs)'s runtime engine.
//!
//! The build environment has no native XLA runtime, so this crate keeps
//! the *API* compilable while making the capability boundary explicit:
//!
//! * [`Literal`] construction, reshape and readback are real (pure
//!   host-side buffers) — the conversion helpers and their tests work.
//! * [`PjRtClient::cpu`] succeeds, so artifact-presence checks and
//!   missing-artifact error paths behave exactly as with the real
//!   binding.
//! * Parsing or *executing* an HLO artifact returns [`Error`] with a
//!   clear "stub build" message. All artifact-dependent tests gate on
//!   `artifacts/manifest.txt` and skip cleanly.
//!
//! Swap the `xla` path dependency in the root `Cargo.toml` for a real
//! XLA binding crate to enable the PJRT runtime.

/// Error type mirroring the binding crate's (printable via `{:?}`).
#[derive(Debug, Clone)]
pub struct Error {
    pub msg: String,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

fn stub_err(what: &str) -> Error {
    Error { msg: format!("{what}: XLA runtime not available in this build (vendored stub — see rust/vendor/README.md)") }
}

type Result<T> = std::result::Result<T, Error>;

/// Typed host-side buffer backing a [`Literal`].
#[derive(Debug, Clone)]
enum Buf {
    I32(Vec<i32>),
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

/// Host literal: a typed buffer plus a shape.
#[derive(Debug, Clone)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

/// Element types [`Literal`] can store in this stub.
pub trait NativeType: Copy + Sized {
    fn wrap(vals: Vec<Self>) -> Buf;
    fn unwrap(buf: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn wrap(vals: Vec<i32>) -> Buf {
        Buf::I32(vals)
    }
    fn unwrap(buf: &Buf) -> Option<Vec<i32>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn wrap(vals: Vec<f32>) -> Buf {
        Buf::F32(vals)
    }
    fn unwrap(buf: &Buf) -> Option<Vec<f32>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(vals: &[T]) -> Literal {
        Literal { dims: vec![vals.len() as i64], buf: T::wrap(vals.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(val: T) -> Literal {
        Literal { dims: vec![], buf: T::wrap(vec![val]) }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = match &self.buf {
            Buf::I32(v) => v.len(),
            Buf::F32(v) => v.len(),
            Buf::Tuple(_) => {
                return Err(Error { msg: "reshape of tuple literal".into() })
            }
        };
        if n as usize != have {
            return Err(Error { msg: format!("reshape: {have} elements into {dims:?}") });
        }
        Ok(Literal { buf: self.buf.clone(), dims: dims.to_vec() })
    }

    /// Read the buffer back as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf).ok_or_else(|| Error { msg: "to_vec: element type mismatch".into() })
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.buf {
            Buf::Tuple(elems) => Ok(elems),
            _ => Ok(vec![self]),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err("HLO parse"))
    }
}

/// Computation wrapper (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("buffer readback"))
    }
}

/// Compiled executable handle.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("execute"))
    }
}

/// PJRT client. Creation succeeds (host metadata only); compilation is
/// where the stub reports the missing runtime.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]).reshape(&[2, 3]).unwrap();
        assert_eq!(l.dims(), &[2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn client_creates_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let err = c.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
    }
}
