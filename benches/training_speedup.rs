//! Bench W1 (DESIGN.md §4): the dimensional-function-synthesis headline
//! this paper builds on (Wang et al. [5]) — learning Φ from dimensionless
//! products is cheaper and more accurate than learning from raw signals.
//!
//! For each system we train the identical MLP architecture on (a) Π
//! features from the synthesized hardware and (b) raw signals, and
//! compare on the *physical task*: relative error of the recovered
//! target parameter (period, deflection, …) on held-out traces. The Π
//! model predicts Π₀ and inverts the target-isolating monomial; the raw
//! model predicts the target directly. We report steps to reach 5% mean
//! relative target error (evaluated every 25 steps), the final error,
//! and the arithmetic-operation count of one deployed inference.
//!
//! Requires `make artifacts`.
//!
//! ```text
//! cargo bench --bench training_speedup
//! ```

use dimsynth::bench_util::section;
use dimsynth::newton::corpus;
use dimsynth::runtime::Engine;
use dimsynth::stim::Lfsr32;
use dimsynth::train::{self, build_dataset, param_count, FeatureKind, HIDDEN};

const TOTAL_STEPS: u32 = 600;
const EVAL_EVERY: u32 = 25;
const TARGET_ERR: f64 = 0.05; // 5% mean relative target error

/// Arithmetic ops for one deployed inference.
fn ops_pi(ds: &train::Dataset) -> usize {
    let pre: usize = ds
        .export
        .exponents
        .iter()
        .map(|e| e.iter().map(|x| x.unsigned_abs() as usize).sum::<usize>())
        .sum();
    pre + mlp_ops(ds.dim)
}

fn mlp_ops(dim: usize) -> usize {
    dim * HIDDEN + HIDDEN * HIDDEN + HIDDEN + 2 * HIDDEN + 1
}

struct Outcome {
    steps_to_thr: u32,
    final_err: f64,
    dim: usize,
    params: usize,
    ops: usize,
}

fn run(
    eng: &mut Engine,
    system: &str,
    kind: FeatureKind,
) -> anyhow::Result<Outcome> {
    let ds = build_dataset(system, kind, 1024, 0.01, 0x5EED)?;
    let mut params = train::init_params(ds.dim, 0x5EED);
    let mut rng = Lfsr32::new(0x5EED ^ 0x7A1E);
    let mut curve = Vec::new();
    let mut steps_to_thr = TOTAL_STEPS;
    let mut final_err = f64::NAN;
    let mut step = 0u32;
    while step < TOTAL_STEPS {
        train::sgd_steps(
            eng, &ds, system, &mut params, step, EVAL_EVERY, TOTAL_STEPS, 0.2, 0.01,
            &mut rng, &mut curve,
        )?;
        step += EVAL_EVERY;
        let err = train::eval_target_error(eng, &ds, system, &params, 256, 0xE7)?;
        final_err = err;
        if err < TARGET_ERR && steps_to_thr == TOTAL_STEPS {
            steps_to_thr = step;
        }
    }
    Ok(Outcome {
        steps_to_thr,
        final_err,
        dim: ds.dim,
        params: param_count(ds.dim),
        ops: match kind {
            FeatureKind::Pi => ops_pi(&ds),
            FeatureKind::Raw => mlp_ops(ds.dim),
        },
    })
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut eng = Engine::new("artifacts")?;
    section("Π features vs raw-signal baseline — physical-target accuracy");
    println!(
        "{:<24} {:>5} {:>5} {:>8} {:>11} {:>14} {:>9} {:>10}",
        "system", "feat", "dim", "params", "steps→5%", "final |rel|%", "ops/inf", "speedup"
    );
    let mut speedups = Vec::new();
    let mut acc_wins = 0usize;
    for e in corpus() {
        let pi = run(&mut eng, e.id, FeatureKind::Pi)?;
        let raw = run(&mut eng, e.id, FeatureKind::Raw)?;
        let speedup = raw.steps_to_thr as f64 / pi.steps_to_thr.max(1) as f64;
        speedups.push(speedup);
        if pi.final_err <= raw.final_err {
            acc_wins += 1;
        }
        for (label, o, sp) in
            [("Π", &pi, format!("{speedup:.1}×")), ("raw", &raw, String::new())]
        {
            println!(
                "{:<24} {:>5} {:>5} {:>8} {:>11} {:>14.3} {:>9} {:>10}",
                e.id,
                label,
                o.dim,
                o.params,
                if o.steps_to_thr == TOTAL_STEPS {
                    format!(">{TOTAL_STEPS}")
                } else {
                    o.steps_to_thr.to_string()
                },
                100.0 * o.final_err,
                o.ops,
                sp
            );
        }
    }
    let gm = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!(
        "\ngeometric-mean convergence speedup (steps to {:.0}% target error): {gm:.1}×",
        100.0 * TARGET_ERR
    );
    println!("Π accuracy wins: {acc_wins}/7");
    // Directional claims (Wang et al. [5], which this paper accelerates):
    assert!(gm >= 1.0, "Π features converged slower on average");
    assert!(acc_wins >= 4, "Π features lost accuracy on most systems");
    Ok(())
}
