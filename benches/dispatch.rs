//! Dispatch-lane scaling bench: replay the same Π-heavy four-tenant
//! workload through the real TCP serving stack at K = 1, 2, and
//! one-lane-per-core, tenants pinned round-robin across the K dispatch
//! lanes, and report aggregate throughput plus the worst per-tenant p99
//! at each K. Emits `BENCH_dispatch.json`.
//!
//! Always asserted, any size: every request gets exactly one typed
//! answer, nothing is shed (the tenants are unlimited and self-clocked),
//! and graceful drain leaves `terminal == admitted` at every K.
//!
//! ```text
//! cargo bench --bench dispatch                        # full sweep
//! DISPATCH_REQUESTS=8000 cargo bench --bench dispatch # scaled smoke
//! DISPATCH_REQUIRE_LANE_SPEEDUP=1 ...                 # gate K>1 beats K=1
//! ```
//!
//! The speedup gate is opt-in because it needs real parallel cores: on
//! a single-core runner the lanes time-slice and the sweep only checks
//! the invariants.

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::coordinator::net::run_driver;
use dimsynth::coordinator::{
    AdmissionConfig, DriverConfig, DriverReport, EngineConfig, FaultPlan, NetServer,
    ServeSet, TenantSpec, TrafficEngine,
};
use dimsynth::flow::FlowConfig;
use dimsynth::synth::LaneWidth;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

const TENANTS: usize = 4;
const SYSTEMS: [&str; TENANTS] = ["pendulum", "spring_mass", "pendulum", "spring_mass"];

struct LaneRun {
    /// Requested dispatcher count (the engine may clamp to the tenant
    /// count; `lanes` is what actually ran).
    k: usize,
    lanes: usize,
    rps: f64,
    worst_p99_us: u64,
}

/// One sweep point: boot a fresh engine at `k` dispatch lanes over the
/// shared warm `set`, replay `per_tenant` Π requests from each of the
/// four pinned tenants concurrently, check the serving invariants, and
/// measure aggregate throughput.
fn run_at(set: &ServeSet, k: usize, per_tenant: usize) -> anyhow::Result<LaneRun> {
    let tenants: Vec<TenantSpec> = (0..TENANTS)
        .map(|i| {
            TenantSpec::new(&format!("t{i}"), SYSTEMS[i])
                .with_queue_cap(8192)
                .with_lane(i % k)
        })
        .collect();
    let admission =
        AdmissionConfig { tenants, default_deadline: Duration::from_secs(60) };
    let engine = Arc::new(TrafficEngine::start(
        set,
        admission,
        EngineConfig { activations: 2, max_batch: 16, dispatchers: k },
        FaultPlan::none(),
    )?);
    let lanes = engine.lane_count();
    let server = NetServer::start(engine, "127.0.0.1:0")?;
    let addr = server.local_addr().to_string();

    let t = Instant::now();
    let joins: Vec<_> = (0..TENANTS)
        .map(|i| {
            let addr = addr.clone();
            let sys = set.system_index(SYSTEMS[i]).expect("corpus system");
            let ports = set.handle_at(sys).design().num_inputs();
            let cfg = DriverConfig {
                requests: per_tenant,
                window: 32,
                seed: 0xD15 ^ (i as u32 + 1),
                // Π-heavy on purpose: power floods serialize on the
                // shared flood gate, Π batches are where lanes scale.
                power_ratio: 0.0,
                deadline_us: 60_000_000,
                ..DriverConfig::new(&format!("t{i}"), ports)
            };
            std::thread::spawn(move || run_driver(&addr, &cfg).unwrap())
        })
        .collect();
    let reports: Vec<DriverReport> =
        joins.into_iter().map(|j| j.join().expect("driver thread")).collect();
    let wall = t.elapsed().max(Duration::from_nanos(1));

    let sent: u64 = reports.iter().map(|r| r.sent).sum();
    let mut worst_p99_us = 0;
    for (i, r) in reports.iter().enumerate() {
        assert_eq!(r.answered(), r.sent, "t{i}: a request went unanswered: {r:?}");
        assert_eq!(r.ok, r.sent, "t{i} is unlimited and self-clocked: {r:?}");
        worst_p99_us = worst_p99_us.max(r.latency.percentile_us(0.99));
    }

    let report = server.shutdown();
    assert!(!report.engine_panicked);
    assert_eq!(report.lanes.len(), lanes);
    for tn in &report.tenants {
        assert_eq!(
            tn.counters.terminal(),
            tn.counters.admitted,
            "tenant `{}` drained dirty at K={k}: {:?}",
            tn.tenant,
            tn.counters
        );
        assert_eq!(tn.queue_depth, 0, "tenant `{}` queue not drained", tn.tenant);
    }

    let rps = sent as f64 / wall.as_secs_f64();
    println!(
        "K={k} ({lanes} lane{}) replayed {sent} requests in {} ({rps:.0} req/s, worst p99 {worst_p99_us} µs)",
        if lanes == 1 { "" } else { "s" },
        fmt_duration(wall)
    );
    Ok(LaneRun { k, lanes, rps, worst_p99_us })
}

fn main() -> anyhow::Result<()> {
    let total = env_u64("DISPATCH_REQUESTS", 40_000) as usize;
    let per_tenant = (total / TENANTS).max(50);
    let require_speedup =
        std::env::var("DISPATCH_REQUIRE_LANE_SPEEDUP").is_ok_and(|v| v == "1");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);

    let mut ks = vec![1, 2, cores.max(2)];
    ks.sort_unstable();
    ks.dedup();

    section(&format!(
        "dispatch sweep: {} Π requests x {TENANTS} tenants at K = {ks:?}",
        per_tenant * TENANTS
    ));

    // One warm ServeSet shared by every sweep point: the sweep measures
    // dispatch, not compilation.
    let config = FlowConfig {
        power_samples: 2,
        lane_width: LaneWidth::W64,
        ..FlowConfig::default()
    };
    let set = ServeSet::boot(&["pendulum", "spring_mass"], config, None)?;

    let mut runs = Vec::new();
    for &k in &ks {
        runs.push(run_at(&set, k, per_tenant)?);
    }

    let k1 = runs.iter().find(|r| r.k == 1).expect("K=1 baseline").rps;
    let best_multi =
        runs.iter().filter(|r| r.k > 1).map(|r| r.rps).fold(0.0_f64, f64::max);
    let speedup = best_multi / k1;
    println!("best multi-lane speedup over K=1: {speedup:.2}x");
    if require_speedup {
        assert!(
            best_multi > k1,
            "lane speedup gate: best multi-lane {best_multi:.0} req/s \
             does not beat K=1 {k1:.0} req/s"
        );
        println!("lane speedup gate: passed ({speedup:.2}x)");
    }

    let mut metrics: Vec<(String, f64)> = vec![
        ("requests_per_k".to_string(), (per_tenant * TENANTS) as f64),
        ("tenants".to_string(), TENANTS as f64),
        ("speedup_best_vs_k1".to_string(), speedup),
        ("speedup_gated".to_string(), if require_speedup { 1.0 } else { 0.0 }),
    ];
    for r in &runs {
        metrics.push((format!("req_per_s_k{}", r.k), r.rps));
        metrics.push((format!("worst_p99_us_k{}", r.k), r.worst_p99_us as f64));
        metrics.push((format!("lanes_k{}", r.k), r.lanes as f64));
    }
    let entries: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_metrics_json(
        "BENCH_dispatch.json",
        &[("driver", "net-dispatch"), ("systems", "pendulum+spring_mass")],
        &entries,
    )?;
    println!("wrote BENCH_dispatch.json");
    Ok(())
}
