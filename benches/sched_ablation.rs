//! Bench A1 (DESIGN.md §4): scheduling-policy ablation.
//!
//! The paper's design parallelizes across Π products and serializes ops
//! within each product. This ablation compares it against a fully serial
//! schedule (one shared datapath) on latency, and quantifies the area
//! cost of the parallel choice, plus the effect of the cost-directed
//! basis optimization (pisearch::reduce) on latency.
//!
//! ```text
//! cargo bench --bench sched_ablation
//! ```

use dimsynth::bench_util::section;
use dimsynth::fixedpoint::Q16_15;
use dimsynth::newton::{corpus, load_entry};
use dimsynth::pisearch::{self, CostModel};
use dimsynth::rtl::{self, Policy};
use dimsynth::synth;

fn main() -> anyhow::Result<()> {
    section("scheduling policy: parallel-per-Π (paper) vs fully-serial");
    println!(
        "{:<24} {:>4} {:>12} {:>12} {:>10} {:>12}",
        "system", "N", "par cycles", "ser cycles", "ser/par", "par cells"
    );
    for e in corpus() {
        let model = load_entry(&e)?;
        let analysis = pisearch::analyze_optimized(&model, e.target)?;
        let design = rtl::build(&analysis, Q16_15);
        let par = rtl::module_latency(&design, Policy::ParallelPerPi);
        let ser = rtl::module_latency(&design, Policy::FullySerial);
        let cells = synth::map_design(&design).lut4_cells;
        println!(
            "{:<24} {:>4} {:>12} {:>12} {:>10.2} {:>12}",
            e.id,
            analysis.n(),
            par,
            ser,
            ser as f64 / par as f64,
            cells
        );
        assert!(ser >= par);
    }

    section("basis optimization: raw Buckingham basis vs cost-directed");
    println!(
        "{:<24} {:>14} {:>14} {:>10}",
        "system", "raw cycles", "optimized", "gain"
    );
    for e in corpus() {
        let model = load_entry(&e)?;
        let raw = pisearch::analyze(&model, e.target)?;
        let mut opt = raw.clone();
        pisearch::optimize(&mut opt, &CostModel::default());
        let d_raw = rtl::build(&raw, Q16_15);
        let d_opt = rtl::build(&opt, Q16_15);
        let l_raw = rtl::module_latency(&d_raw, Policy::ParallelPerPi);
        let l_opt = rtl::module_latency(&d_opt, Policy::ParallelPerPi);
        println!(
            "{:<24} {:>14} {:>14} {:>9.0}%",
            e.id,
            l_raw,
            l_opt,
            100.0 * (l_raw as f64 - l_opt as f64) / l_raw as f64
        );
        assert!(l_opt <= l_raw, "{}: optimization regressed latency", e.id);
    }
    Ok(())
}
