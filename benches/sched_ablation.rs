//! Bench A1 (DESIGN.md §4): scheduling-policy ablation.
//!
//! The paper's design parallelizes across Π products and serializes ops
//! within each product. This ablation compares it against a fully serial
//! schedule (one shared datapath) on latency, and quantifies the area
//! cost of the parallel choice, plus the effect of the cost-directed
//! basis optimization (pisearch::reduce) on latency. Both ablation axes
//! are [`FlowConfig`] knobs — `policy` and `optimize_basis` — so each
//! comparison is two queries against sessions differing in one knob.
//!
//! ```text
//! cargo bench --bench sched_ablation
//! ```

use dimsynth::bench_util::section;
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton::corpus;
use dimsynth::rtl::Policy;

fn main() -> anyhow::Result<()> {
    section("scheduling policy: parallel-per-Π (paper) vs fully-serial");
    println!(
        "{:<24} {:>4} {:>12} {:>12} {:>10} {:>12}",
        "system", "N", "par cycles", "ser cycles", "ser/par", "par cells"
    );
    for e in corpus() {
        let mut flow = Flow::for_entry(e.clone(), FlowConfig::default());
        let n = flow.pis()?.n();
        let par = flow.latency()?;
        flow.set_policy(Policy::FullySerial);
        let ser = flow.latency()?;
        let cells = flow.netlist()?.lut4_cells;
        println!(
            "{:<24} {:>4} {:>12} {:>12} {:>10.2} {:>12}",
            e.id,
            n,
            par,
            ser,
            ser as f64 / par as f64,
            cells
        );
        assert!(ser >= par);
    }

    section("basis optimization: raw Buckingham basis vs cost-directed");
    println!(
        "{:<24} {:>14} {:>14} {:>10}",
        "system", "raw cycles", "optimized", "gain"
    );
    for e in corpus() {
        let mut raw = Flow::for_entry(
            e.clone(),
            FlowConfig { optimize_basis: false, ..FlowConfig::default() },
        );
        let mut opt = Flow::for_entry(e.clone(), FlowConfig::default());
        let l_raw = raw.latency()?;
        let l_opt = opt.latency()?;
        println!(
            "{:<24} {:>14} {:>14} {:>9.0}%",
            e.id,
            l_raw,
            l_opt,
            100.0 * (l_raw as f64 - l_opt as f64) / l_raw as f64
        );
        assert!(l_opt <= l_raw, "{}: optimization regressed latency", e.id);
    }
    Ok(())
}
