//! Bench F6: the multi-system serving path — cold vs warm [`ServeSet`]
//! boot against a persistent artifact store, and cross-system vs
//! per-system power-flood dispatch. Emits `BENCH_serve.json` so CI can
//! track the serving front half's perf trajectory; CI also gates the
//! warm boot at zero stage recomputes.
//!
//! Needs no AOT artifacts — boot is pure compilation and the flood is
//! pure gate-level simulation.
//!
//! ```text
//! cargo bench --bench serve
//! SERVE_BENCH_ACTIVATIONS=4 cargo bench --bench serve
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::coordinator::{
    estimate_power_requests, PowerRequest, ServeSet, SystemPowerRequest,
};
use dimsynth::flow::{ArtifactStore, FlowConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

const SYSTEMS: [&str; 3] = ["pendulum", "beam", "spring_mass"];

fn main() -> anyhow::Result<()> {
    let activations: u32 = std::env::var("SERVE_BENCH_ACTIVATIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let config =
        FlowConfig { power_samples: activations, ..FlowConfig::default() };

    section(&format!(
        "multi-system serving: {} systems on one warm FlowSet ({activations} activations)",
        SYSTEMS.len()
    ));

    // Cold boot populates the store; warm boot is what a restarted
    // serve process pays.
    let cache_dir =
        std::env::temp_dir().join(format!("dimsynth-serve-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let store = Arc::new(ArtifactStore::open(&cache_dir)?);
    let t = Instant::now();
    let cold = ServeSet::boot(&SYSTEMS, config.clone(), Some(store))?;
    let cold_boot = t.elapsed();
    println!(
        "cold serve boot     {:>12}  ({} recomputes)",
        fmt_duration(cold_boot),
        cold.total_counts().recomputes()
    );
    drop(cold);

    let store = Arc::new(ArtifactStore::open(&cache_dir)?);
    let t = Instant::now();
    let set = ServeSet::boot(&SYSTEMS, config, Some(store))?;
    let warm_boot = t.elapsed().max(Duration::from_nanos(1));
    let warm_counts = set.total_counts();
    assert_eq!(
        warm_counts.recomputes(),
        0,
        "warm serve boot must recompute nothing: {warm_counts:?}"
    );
    let boot_speedup = cold_boot.as_secs_f64() / warm_boot.as_secs_f64();
    println!(
        "warm serve boot     {:>12}  ({boot_speedup:.1}x faster, {} disk hits, 0 recomputes)",
        fmt_duration(warm_boot),
        warm_counts.disk_hits
    );

    // Mixed flood, round-robin across systems: cross-system dispatch
    // (all chunks share one worker fan-out) vs the per-system shape the
    // coordinator had before (each system's flood dispatched on its
    // own).
    let flood: Vec<SystemPowerRequest> = (0..(3 * set.lane_width().lanes()))
        .map(|i| SystemPowerRequest {
            system: i % SYSTEMS.len(),
            request: PowerRequest { seed: 0xF10_0D ^ i as u32, f_hz: 6.0e6 },
        })
        .collect();

    let t = Instant::now();
    let cross = set.estimate_power_flood(&flood, activations)?;
    let cross_dt = t.elapsed().max(Duration::from_nanos(1));
    let cross_rps = flood.len() as f64 / cross_dt.as_secs_f64();
    println!(
        "cross-system flood  {:>12}  ({} requests, {cross_rps:.0} req/s)",
        fmt_duration(cross_dt),
        flood.len()
    );

    let t = Instant::now();
    let mut per_system = vec![
        dimsynth::coordinator::PowerEstimate { mw: 0.0, toggles_per_cycle: 0.0, cycles: 0 };
        flood.len()
    ];
    for sys in 0..SYSTEMS.len() {
        let handle = set.handle_at(sys);
        let positions: Vec<usize> =
            (0..flood.len()).filter(|&i| flood[i].system == sys).collect();
        let own: Vec<PowerRequest> = positions.iter().map(|&i| flood[i].request).collect();
        let solo = estimate_power_requests(
            handle.netlist(),
            handle.design(),
            &own,
            activations,
            set.lane_width(),
        );
        for (&pos, est) in positions.iter().zip(solo) {
            per_system[pos] = est;
        }
    }
    let per_dt = t.elapsed().max(Duration::from_nanos(1));
    let per_rps = flood.len() as f64 / per_dt.as_secs_f64();
    let flood_speedup = per_dt.as_secs_f64() / cross_dt.as_secs_f64();
    println!(
        "per-system floods   {:>12}  ({per_rps:.0} req/s; cross-system is {flood_speedup:.2}x)",
        fmt_duration(per_dt)
    );

    // The whole point of the shared batcher: same answers, one fan-out.
    for (i, (a, b)) in cross.iter().zip(&per_system).enumerate() {
        assert_eq!(a.mw, b.mw, "request {i}");
        assert_eq!(a.toggles_per_cycle, b.toggles_per_cycle, "request {i}");
        assert_eq!(a.cycles, b.cycles, "request {i}");
    }
    let _ = std::fs::remove_dir_all(&cache_dir);

    write_metrics_json(
        "BENCH_serve.json",
        &[("driver", "serveset"), ("systems", "pendulum+beam+spring_mass")],
        &[
            ("systems", SYSTEMS.len() as f64),
            ("activations", activations as f64),
            ("lanes", set.lane_width().lanes() as f64),
            ("flood_requests", flood.len() as f64),
            ("cold_boot_ms", cold_boot.as_secs_f64() * 1e3),
            ("warm_boot_ms", warm_boot.as_secs_f64() * 1e3),
            ("warm_boot_speedup", boot_speedup),
            ("warm_disk_hits", warm_counts.disk_hits as f64),
            ("warm_recomputes", warm_counts.recomputes() as f64),
            ("cross_flood_rps", cross_rps),
            ("per_system_flood_rps", per_rps),
            ("cross_vs_per_system_speedup", flood_speedup),
        ],
    )?;
    println!("wrote BENCH_serve.json");

    // Wall-clock ratios on shared runners are noisy; the boot speedup
    // is the structural one (disk load vs full compile) and must hold.
    assert!(
        boot_speedup >= 2.0,
        "warm serve boot must be much faster than cold (got {boot_speedup:.1}x)"
    );
    Ok(())
}
