//! Bench T1 (DESIGN.md §4): regenerate the paper's Table 1 and time each
//! stage of the synthesis flow.
//!
//! ```text
//! cargo bench --bench table1
//! ```

use dimsynth::bench_util::{bench_auto, section};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::report;
use dimsynth::rtl;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    section("Table 1 — reproduced vs paper (measured in parentheses = paper)");
    let rows = report::generate_table(Q16_15, 4)?;
    println!("{}", report::render_markdown(&rows));

    section("shape checks (paper §3.A prose)");
    let get = |id: &str| rows.iter().find(|r| r.id == id).unwrap();
    let checks: Vec<(&str, bool)> = vec![
        ("all latencies < 300 cycles", rows.iter().all(|r| r.latency_cycles < 300)),
        ("all P@12MHz < 6.5 mW", rows.iter().all(|r| r.power_12mhz_mw < 6.5)),
        ("all P@6MHz ≥ 1.0 mW order", rows.iter().all(|r| r.power_6mhz_mw > 0.3)),
        (
            ">10k samples/s at 6 MHz",
            rows.iter().all(|r| 6.0e6 / r.latency_cycles as f64 > 10_000.0),
        ),
        (
            "flight finishes faster than pendulum (parallel Π datapaths)",
            get("unpowered_flight").latency_cycles < get("pendulum").latency_cycles,
        ),
        (
            "fluid-in-pipe is the largest design",
            rows.iter().all(|r| r.lut4_cells <= get("fluid_pipe").lut4_cells),
        ),
        (
            "pendulum and spring-mass are the smallest designs",
            {
                let mut cells: Vec<(usize, &str)> =
                    rows.iter().map(|r| (r.lut4_cells, r.id.as_str())).collect();
                cells.sort();
                let low2: Vec<&str> = cells[..2].iter().map(|c| c.1).collect();
                low2.contains(&"pendulum") && low2.contains(&"spring_mass")
            },
        ),
        (
            "Fmax in the paper's 15–18 MHz band",
            rows.iter().all(|r| r.fmax_mhz > 14.0 && r.fmax_mhz < 18.5),
        ),
    ];
    let mut all = true;
    for (name, ok) in checks {
        println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
        all &= ok;
    }
    assert!(all, "Table-1 shape checks failed");

    section("flow-stage timings (pendulum)");
    // A warm session provides each stage's input artifact; the timed
    // closures then run exactly one stage's compute kernel, so the
    // figures are per-stage costs, not cumulative pipeline costs.
    let budget = Duration::from_millis(300);
    let mut warm = Flow::for_system("pendulum", FlowConfig::default())?;
    let model = warm.parsed()?.clone();
    let target = warm.target().to_string();
    let analysis = warm.pis()?.clone();
    let design = warm.rtl()?.clone();
    println!("{}", bench_auto("frontend: parse+sema", budget, || {
        let mut f = Flow::for_system("pendulum", FlowConfig::default()).unwrap();
        let _ = f.parsed().unwrap();
    }));
    println!("{}", bench_auto("pisearch: nullspace+optimize", budget, || {
        let _ = dimsynth::pisearch::analyze_optimized(&model, &target).unwrap();
    }));
    println!("{}", bench_auto("rtl: build+emit verilog", budget, || {
        let d = rtl::build(&analysis, Q16_15);
        let _ = rtl::verilog::emit(&d);
    }));
    println!("{}", bench_auto("synth: lower+opt+techmap", budget, || {
        let _ = dimsynth::synth::map_design(&design);
    }));
    Ok(())
}
