//! Bench A2 (DESIGN.md §4): fixed-point width sweep.
//!
//! The paper: "The compiler backend is fully parametric with respect to
//! the length of the fixed point representation … This will allow future
//! designs to tailor the precision of the compute modules to the
//! requirements of the inference algorithms." This bench quantifies that
//! design space on the pendulum and beam systems: cells / Fmax / latency
//! / Π accuracy (vs f64) as the format sweeps Q8.7 → Q24.23.
//!
//! ```text
//! cargo bench --bench width_sweep
//! ```

use dimsynth::bench_util::section;
use dimsynth::fixedpoint::{self, QFormat};
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::power;
use dimsynth::stim::{self, Lfsr32, LfsrBank};
use dimsynth::synth::{LaneWord, W256};
use std::time::Instant;

const FORMATS: [(u32, u32); 5] = [(8, 7), (12, 11), (16, 15), (20, 19), (24, 23)];

/// Streams-simulated-per-second of one batched power measurement at lane
/// width `W` (the lane-width axis of the sweep; the format axis is the
/// table above).
fn streams_per_sec<W: LaneWord>(flow: &mut Flow, activations: u32) -> anyhow::Result<f64> {
    let design = flow.rtl()?.clone();
    let mapped = flow.netlist()?;
    let seeds = LfsrBank::<W>::lane_seeds(0xACE1);
    let t = Instant::now();
    let act = power::measure_activity_batch_wide::<W>(
        &mapped.netlist,
        &design,
        activations,
        &seeds,
        None,
    );
    let dt = t.elapsed().as_secs_f64();
    assert!(act.cycles > 0);
    Ok(W::LANES as f64 / dt)
}

fn main() -> anyhow::Result<()> {
    for sys in ["pendulum", "beam"] {
        section(&format!("width sweep — {sys}"));
        println!(
            "{:<8} {:>7} {:>9} {:>9} {:>9} {:>12} {:>14}",
            "format", "width", "cells", "Fmax", "latency", "rel err", "range ok %"
        );
        // One session per system: the sweep only invalidates RTL and
        // downstream; parse and Π-search run once for all five formats.
        let mut flow = Flow::for_system(sys, FlowConfig::default())?;
        let mut prev_err = f64::INFINITY;
        for (i, f) in FORMATS {
            let q = QFormat::new(i, f);
            flow.set_qformat(q);
            let cells = flow.netlist()?.lut4_cells;
            let t = flow.timing()?;
            let lat = flow.latency()?;
            let design = flow.rtl()?;

            // Π accuracy vs f64 on physical traces.
            let mut rng = Lfsr32::new(0xFACE);
            let mut err = 0f64;
            let mut n = 0usize;
            let mut in_range = 0usize;
            let trials = 200;
            for _ in 0..trials {
                let s = stim::sample(sys, &mut rng).unwrap();
                let qv: Vec<i64> = design
                    .ports
                    .iter()
                    .map(|p| q.from_f64(s[p.symbol_index]))
                    .collect();
                if design
                    .ports
                    .iter()
                    .all(|p| s[p.symbol_index].abs() < q.max_value() * 0.9)
                {
                    in_range += 1;
                }
                for u in &design.units {
                    let fx = q.to_f64(fixedpoint::eval_monomial(q, &qv, &u.exponents));
                    let fl: f64 = u
                        .exponents
                        .iter()
                        .enumerate()
                        .map(|(pi, &e)| s[design.ports[pi].symbol_index].powi(e as i32))
                        .product();
                    if fl.abs() > 1e-6 {
                        err += ((fx - fl) / fl).abs();
                        n += 1;
                    }
                }
            }
            let rel = err / n.max(1) as f64;
            println!(
                "Q{i}.{f:<4} {:>7} {:>9} {:>8.2}M {:>9} {:>12.2e} {:>13.0}%",
                q.width(),
                cells,
                t.fmax_mhz,
                lat,
                rel,
                100.0 * in_range as f64 / trials as f64
            );
            // Monotonicity within the well-ranged formats: more fraction
            // bits → better accuracy (Q8.7 can saturate on beam signals,
            // so only enforce once the dynamic range fits).
            if in_range == trials && prev_err.is_finite() {
                assert!(rel <= prev_err * 1.5, "{sys}: accuracy regressed at Q{i}.{f}");
            }
            if in_range == trials {
                prev_err = rel;
            }
        }

        // Return trip: revisit every format in reverse. The per-stage
        // LRU (deeper than the 5-format sweep) must serve all of them —
        // zero additional recomputes.
        let counts_after_sweep = flow.counts();
        for (i, f) in FORMATS.iter().rev() {
            flow.set_qformat(QFormat::new(*i, *f));
            flow.netlist()?;
            flow.timing()?;
        }
        let counts_after_return = flow.counts();
        assert_eq!(
            counts_after_return.recomputes(),
            counts_after_sweep.recomputes(),
            "{sys}: return trips must hit the per-stage LRU, not recompute"
        );
        println!(
            "return trip: 0 recomputes ({} LRU promotions)",
            counts_after_return.memory_hits - counts_after_sweep.memory_hits
        );

        // Lane-width axis at the paper format: simulation throughput in
        // independent stimulus streams per second, 64 vs 256 lanes (the
        // gatesim bench owns the JSON series; this prints the per-system
        // comparison alongside the format sweep).
        flow.set_qformat(QFormat::new(16, 15));
        let s64 = streams_per_sec::<u64>(&mut flow, 8)?;
        let s256 = streams_per_sec::<W256>(&mut flow, 8)?;
        println!(
            "lane width @Q16.15: {s64:.1} streams/s at 64 lanes, {s256:.1} at 256 ({:.2}x)",
            s256 / s64
        );
    }
    Ok(())
}
