//! Bench F1: compilation-session throughput — cold vs memoized full-corpus
//! flow, and sequential vs parallel [`FlowSet`] driving. Emits
//! `BENCH_flow.json` so CI can track the session API's perf trajectory.
//!
//! Needs no artifacts — this is the pure compilation path.
//!
//! ```text
//! cargo bench --bench flow
//! FLOW_BENCH_SAMPLES=4 cargo bench --bench flow
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::flow::{worker, Flow, FlowConfig, FlowSet};
use std::time::{Duration, Instant};

/// Query every stage of one session (the full Table-1 workload).
fn drive(flow: &mut Flow) -> (usize, f64, f64) {
    let cells = flow.netlist().unwrap().lut4_cells;
    let fmax = flow.timing().unwrap().fmax_mhz;
    let mw = flow.power().unwrap().mw_6mhz;
    flow.latency().unwrap();
    (cells, fmax, mw)
}

fn main() -> anyhow::Result<()> {
    let samples: u32 = std::env::var("FLOW_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let config = FlowConfig { power_samples: samples, ..FlowConfig::default() };
    let cores = worker::worker_count(usize::MAX);

    section(&format!(
        "full-corpus compilation flow ({samples} power samples, {cores} cores)"
    ));

    // Cold sequential: every stage of every system computes from source.
    let mut set = FlowSet::corpus(config.clone());
    let t = Instant::now();
    let cold_rows = set.run_sequential(drive);
    let cold = t.elapsed();
    println!("cold sequential     {:>12}  ({} systems)", fmt_duration(cold), cold_rows.len());

    // Memoized re-query of the same sessions: every stage is a cache hit.
    let t = Instant::now();
    let warm_rows = set.run_sequential(drive);
    let warm = t.elapsed().max(Duration::from_nanos(1));
    assert_eq!(cold_rows, warm_rows, "memoized results must be identical");
    let memo_speedup = cold.as_secs_f64() / warm.as_secs_f64();
    println!("memoized re-query   {:>12}  ({memo_speedup:.0}x faster)", fmt_duration(warm));

    // Cold parallel: fresh sessions, one flow per scoped worker.
    let mut pset = FlowSet::corpus(config);
    let t = Instant::now();
    let par_rows = pset.run_parallel(drive);
    let par = t.elapsed().max(Duration::from_nanos(1));
    assert_eq!(cold_rows, par_rows, "parallel results must be identical");
    let par_speedup = cold.as_secs_f64() / par.as_secs_f64();
    println!("cold parallel       {:>12}  ({par_speedup:.2}x vs sequential)", fmt_duration(par));

    write_metrics_json(
        "BENCH_flow.json",
        &[("driver", "flowset"), ("corpus", "table1-7sys")],
        &[
            ("systems", cold_rows.len() as f64),
            ("power_samples", samples as f64),
            ("cores", cores as f64),
            ("cold_sequential_ms", cold.as_secs_f64() * 1e3),
            ("memoized_requery_ms", warm.as_secs_f64() * 1e3),
            ("cold_parallel_ms", par.as_secs_f64() * 1e3),
            ("memoized_speedup", memo_speedup),
            ("parallel_speedup", par_speedup),
        ],
    )?;
    println!("wrote BENCH_flow.json");

    assert!(
        memo_speedup >= 10.0,
        "memoized re-query must be >=10x faster than a cold run (got {memo_speedup:.1}x)"
    );
    // The parallel-vs-sequential ratio is a wall-clock measurement of two
    // short runs; on a loaded shared runner it can dip below 1.0 without
    // any code defect, so it is recorded in BENCH_flow.json and warned
    // about rather than asserted.
    if cores > 1 && par_speedup <= 1.0 {
        eprintln!(
            "warning: parallel cold run not faster than sequential \
             ({par_speedup:.2}x on {cores} cores) — noisy host?"
        );
    }
    Ok(())
}
