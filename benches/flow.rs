//! Bench F1: compilation-session throughput — cold vs memoized full-corpus
//! flow, sequential vs parallel [`FlowSet`] driving, and disk-cold vs
//! disk-warm runs against the persistent artifact store. Emits
//! `BENCH_flow.json` so CI can track the session API's perf trajectory.
//!
//! Needs no artifacts — this is the pure compilation path.
//!
//! ```text
//! cargo bench --bench flow
//! FLOW_BENCH_SAMPLES=4 cargo bench --bench flow
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::flow::{worker, ArtifactStore, Flow, FlowConfig, FlowSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Query every stage of one session (the full Table-1 workload).
fn drive(flow: &mut Flow) -> (usize, f64, f64) {
    let cells = flow.netlist().unwrap().lut4_cells;
    let fmax = flow.timing().unwrap().fmax_mhz;
    let mw = flow.power().unwrap().mw_6mhz;
    flow.latency().unwrap();
    (cells, fmax, mw)
}

fn main() -> anyhow::Result<()> {
    let samples: u32 = std::env::var("FLOW_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let config = FlowConfig { power_samples: samples, ..FlowConfig::default() };
    let cores = worker::worker_count(usize::MAX);

    section(&format!(
        "full-corpus compilation flow ({samples} power samples, {cores} cores)"
    ));

    // Cold sequential: every stage of every system computes from source.
    let mut set = FlowSet::corpus(config.clone());
    let t = Instant::now();
    let cold_rows = set.run_sequential(drive);
    let cold = t.elapsed();
    println!("cold sequential     {:>12}  ({} systems)", fmt_duration(cold), cold_rows.len());

    // Memoized re-query of the same sessions: every stage is a cache hit.
    let t = Instant::now();
    let warm_rows = set.run_sequential(drive);
    let warm = t.elapsed().max(Duration::from_nanos(1));
    assert_eq!(cold_rows, warm_rows, "memoized results must be identical");
    let memo_speedup = cold.as_secs_f64() / warm.as_secs_f64();
    println!("memoized re-query   {:>12}  ({memo_speedup:.0}x faster)", fmt_duration(warm));

    // Cold parallel: fresh sessions, one flow per scoped worker.
    let mut pset = FlowSet::corpus(config.clone());
    let t = Instant::now();
    let par_rows = pset.run_parallel(drive);
    let par = t.elapsed().max(Duration::from_nanos(1));
    assert_eq!(cold_rows, par_rows, "parallel results must be identical");
    let par_speedup = cold.as_secs_f64() / par.as_secs_f64();
    println!("cold parallel       {:>12}  ({par_speedup:.2}x vs sequential)", fmt_duration(par));

    // Persistent store: disk-cold populates, then a disk-warm restart
    // (fresh sessions, re-opened store — what a second process sees)
    // must serve every stage from disk with zero recomputes.
    let cache_dir =
        std::env::temp_dir().join(format!("dimsynth-flow-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let store = Arc::new(ArtifactStore::open(&cache_dir)?);
    let mut dset = FlowSet::corpus(config.clone()).with_store(store);
    let t = Instant::now();
    let disk_cold_rows = dset.run_sequential(drive);
    let disk_cold = t.elapsed();
    assert_eq!(cold_rows, disk_cold_rows, "store write-back must not change results");
    drop(dset);

    let store = Arc::new(ArtifactStore::open(&cache_dir)?);
    let mut wset = FlowSet::corpus(config).with_store(store);
    let t = Instant::now();
    let disk_warm_rows = wset.run_sequential(drive);
    let disk_warm = t.elapsed().max(Duration::from_nanos(1));
    assert_eq!(cold_rows, disk_warm_rows, "disk-warm results must be bit-identical");
    let warm_counts = wset.total_counts();
    assert_eq!(warm_counts.recomputes(), 0, "disk-warm run recomputed: {warm_counts:?}");
    let disk_speedup = cold.as_secs_f64() / disk_warm.as_secs_f64();
    println!(
        "disk-cold populate  {:>12}  (store at {})",
        fmt_duration(disk_cold),
        cache_dir.display()
    );
    println!(
        "disk-warm restart   {:>12}  ({disk_speedup:.1}x vs cold, {} disk hits, 0 recomputes)",
        fmt_duration(disk_warm),
        warm_counts.disk_hits
    );
    // Static-verifier stage on the warm sessions: every prerequisite is
    // already a cache hit, so the cold number times the four analyze
    // passes themselves (and the store write-back); the re-query must be
    // a pure memo hit. Runs after the zero-recompute assert above —
    // `drive` never queries analysis, so this is the stage's first
    // computation against this store.
    let t = Instant::now();
    let reports = wset.run_sequential(|flow| flow.analysis().unwrap());
    let analyze_cold = t.elapsed().max(Duration::from_nanos(1));
    assert!(
        reports.iter().all(|r| r.is_clean()),
        "pristine corpus must analyze clean"
    );
    let t = Instant::now();
    let requeried = wset.run_sequential(|flow| flow.analysis().unwrap());
    let analyze_warm = t.elapsed().max(Duration::from_nanos(1));
    assert_eq!(reports, requeried, "memoized analysis must be identical");
    println!(
        "analyze cold        {:>12}  ({} systems, all clean)",
        fmt_duration(analyze_cold),
        reports.len()
    );
    println!("analyze memoized    {:>12}", fmt_duration(analyze_warm));
    let _ = std::fs::remove_dir_all(&cache_dir);

    write_metrics_json(
        "BENCH_flow.json",
        &[("driver", "flowset"), ("corpus", "table1-7sys")],
        &[
            ("systems", cold_rows.len() as f64),
            ("power_samples", samples as f64),
            ("cores", cores as f64),
            ("cold_sequential_ms", cold.as_secs_f64() * 1e3),
            ("memoized_requery_ms", warm.as_secs_f64() * 1e3),
            ("cold_parallel_ms", par.as_secs_f64() * 1e3),
            ("disk_cold_ms", disk_cold.as_secs_f64() * 1e3),
            ("disk_warm_ms", disk_warm.as_secs_f64() * 1e3),
            ("disk_warm_hits", warm_counts.disk_hits as f64),
            ("analyze_cold_ms", analyze_cold.as_secs_f64() * 1e3),
            ("analyze_warm_ms", analyze_warm.as_secs_f64() * 1e3),
            ("memoized_speedup", memo_speedup),
            ("parallel_speedup", par_speedup),
            ("disk_warm_speedup", disk_speedup),
        ],
    )?;
    println!("wrote BENCH_flow.json");

    assert!(
        memo_speedup >= 10.0,
        "memoized re-query must be >=10x faster than a cold run (got {memo_speedup:.1}x)"
    );
    // The parallel-vs-sequential ratio is a wall-clock measurement of two
    // short runs; on a loaded shared runner it can dip below 1.0 without
    // any code defect, so it is recorded in BENCH_flow.json and warned
    // about rather than asserted.
    if cores > 1 && par_speedup <= 1.0 {
        eprintln!(
            "warning: parallel cold run not faster than sequential \
             ({par_speedup:.2}x on {cores} cores) — noisy host?"
        );
    }
    Ok(())
}
