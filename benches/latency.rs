//! Bench L1 (DESIGN.md §4): the latency column and the real-time claim —
//! cycle counts per system (analytic vs simulated), achievable sample
//! rates at 6/12 MHz, and RTL-simulation wall-time per sample. The
//! corpus compiles through one [`FlowSet`] across all cores.
//!
//! ```text
//! cargo bench --bench latency
//! ```

use dimsynth::bench_util::{bench_auto, section};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::{FlowConfig, FlowSet};
use dimsynth::rtl;
use dimsynth::stim::Lfsr32;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    section("cycle counts and sample rates");
    println!(
        "{:<24} {:>8} {:>8} {:>12} {:>12} {:>10}",
        "system", "analytic", "sim", "rate@6MHz", "rate@12MHz", "paper"
    );
    let paper = [
        ("beam", 115u64),
        ("pendulum", 115),
        ("fluid_pipe", 188),
        ("unpowered_flight", 81),
        ("vibrating_string", 183),
        ("warm_vibrating_string", 269),
        ("spring_mass", 115),
    ];
    let mut flows = FlowSet::corpus(FlowConfig::default());
    let rows: Vec<anyhow::Result<(String, u64, u64)>> = flows.run_parallel(|f| {
        let analytic = f.latency()?;
        let design = f.rtl()?;
        let inputs = vec![Q16_15.one(); design.num_inputs()];
        let sim = rtl::run_once(design, &inputs);
        Ok((f.id().to_string(), analytic, sim.cycles))
    });
    for row in rows {
        let (id, analytic, sim_cycles) = row?;
        assert_eq!(analytic, sim_cycles, "{id}: sim/schedule divergence");
        let p = paper.iter().find(|(pid, _)| *pid == id).map(|(_, c)| *c).unwrap();
        println!(
            "{:<24} {:>8} {:>8} {:>12.0} {:>12.0} {:>10}",
            id,
            analytic,
            sim_cycles,
            6.0e6 / analytic as f64,
            12.0e6 / analytic as f64,
            p
        );
        assert!(analytic < 300, "{id}: >300 cycles");
    }

    section("RTL-simulation wall time per sample (cycle-accurate model)");
    let budget = Duration::from_millis(400);
    for f in flows.flows_mut() {
        let id = f.id().to_string();
        let design = f.rtl()?.clone();
        let mut rng = Lfsr32::new(0xA5);
        let r = bench_auto(&format!("rtl-sim {id}"), budget, || {
            let inputs: Vec<i64> = (0..design.num_inputs())
                .map(|_| Q16_15.from_f64(rng.range(0.25, 8.0)))
                .collect();
            let _ = rtl::run_once(&design, &inputs);
        });
        println!("{r}");
    }
    Ok(())
}
