//! Bench L1 (DESIGN.md §4): the latency column and the real-time claim —
//! cycle counts per system (analytic vs simulated), achievable sample
//! rates at 6/12 MHz, and RTL-simulation wall-time per sample.
//!
//! ```text
//! cargo bench --bench latency
//! ```

use dimsynth::bench_util::{bench_auto, section};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::newton::{corpus, load_entry};
use dimsynth::pisearch::analyze_optimized;
use dimsynth::rtl::{self, Policy};
use dimsynth::stim::Lfsr32;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    section("cycle counts and sample rates");
    println!(
        "{:<24} {:>8} {:>8} {:>12} {:>12} {:>10}",
        "system", "analytic", "sim", "rate@6MHz", "rate@12MHz", "paper"
    );
    let paper = [
        ("beam", 115),
        ("pendulum", 115),
        ("fluid_pipe", 188),
        ("unpowered_flight", 81),
        ("vibrating_string", 183),
        ("warm_vibrating_string", 269),
        ("spring_mass", 115),
    ];
    for e in corpus() {
        let model = load_entry(&e)?;
        let analysis = analyze_optimized(&model, e.target)?;
        let design = rtl::build(&analysis, Q16_15);
        let analytic = rtl::module_latency(&design, Policy::ParallelPerPi);
        let inputs = vec![Q16_15.one(); design.num_inputs()];
        let sim = rtl::run_once(&design, &inputs);
        assert_eq!(analytic, sim.cycles, "{}: sim/schedule divergence", e.id);
        let p = paper.iter().find(|(id, _)| *id == e.id).map(|(_, c)| *c).unwrap();
        println!(
            "{:<24} {:>8} {:>8} {:>12.0} {:>12.0} {:>10}",
            e.id,
            analytic,
            sim.cycles,
            6.0e6 / analytic as f64,
            12.0e6 / analytic as f64,
            p
        );
        assert!(analytic < 300, "{}: >300 cycles", e.id);
    }

    section("RTL-simulation wall time per sample (cycle-accurate model)");
    let budget = Duration::from_millis(400);
    for e in corpus() {
        let model = load_entry(&e)?;
        let analysis = analyze_optimized(&model, e.target)?;
        let design = rtl::build(&analysis, Q16_15);
        let mut rng = Lfsr32::new(0xA5);
        let r = bench_auto(&format!("rtl-sim {}", e.id), budget, || {
            let inputs: Vec<i64> = (0..design.num_inputs())
                .map(|_| Q16_15.from_f64(rng.range(0.25, 8.0)))
                .collect();
            let _ = rtl::run_once(&design, &inputs);
        });
        println!("{r}");
    }
    Ok(())
}
