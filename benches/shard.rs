//! Multi-system power-measurement throughput: per-system dispatch (one
//! word-parallel run per corpus member, sequentially — the pre-fusion
//! serving path) vs one fused evaluation of all members (K=1) vs the
//! fused module partitioned across persistent shard workers. Emits
//! `BENCH_shard.json` so CI can track the perf trajectory (member
//! stimulus streams fully simulated per wall-second).
//!
//! Every timed configuration is also checked bit-identical to the
//! per-system reference — the speedup must not come from measuring
//! different physics.
//!
//! Besides throughput, the run reports the cut-aware partitioner and
//! dirty-word exchange: refined vs unrefined cut cost (checked
//! corpus-wide at several K), cut-word count, and words actually
//! published per cycle by the incremental exchange vs the full
//! republication a non-dirty protocol would do.
//!
//! ```text
//! cargo bench --bench shard
//! SHARD_BENCH_ACTIVATIONS=50 cargo bench --bench shard
//! SHARD_BENCH_SHARDS=4 cargo bench --bench shard
//! SHARD_REQUIRE_FUSED_SPEEDUP=1 cargo bench --bench shard   # CI gate:
//! #   fails unless fused+sharded streams/sec strictly beats per-system
//! #   AND refinement never worsens the cut AND the dirty exchange
//! #   publishes strictly fewer words than full republication
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::flow::{ensure_fused, FlowConfig, FlowSet};
use dimsynth::power::{self, LaneActivityReport};
use dimsynth::rtl::PiModuleDesign;
use dimsynth::shard::{
    measure_fused_activity, ExchangeStats, FusedNetlist, MemberStim, ShardPlan, ShardSim,
};
use dimsynth::stim::LfsrBank;
use dimsynth::synth::{Netlist, LANES};
use std::time::{Duration, Instant};

/// Per-member seed bank (distinct lane streams per member, same
/// convention as the differential suite).
fn seeds_of(member: usize) -> Vec<u32> {
    LfsrBank::<u64>::lane_seeds(0xC0FE ^ (member as u32).wrapping_mul(0x9E37_79B9))
}

/// The pre-fusion serving path: one word-parallel measurement per
/// member, one after another.
fn per_system_run(
    members: &[(u64, &Netlist)],
    designs: &[PiModuleDesign],
    activations: u32,
) -> (Vec<LaneActivityReport>, Duration) {
    let t = Instant::now();
    let reports = members
        .iter()
        .enumerate()
        .map(|(m, (_, nl))| {
            power::measure_activity_batch_wide::<u64>(
                nl, &designs[m], activations, &seeds_of(m), None,
            )
        })
        .collect();
    (reports, t.elapsed())
}

/// One sharded evaluation of the fused module, every member's schedule
/// in a single pass. Includes `ShardSim` construction in the timed
/// region — the serving path builds a fresh simulator per round too.
fn fused_run(
    fused: &FusedNetlist,
    plan: &ShardPlan,
    designs: &[PiModuleDesign],
    activations: u32,
) -> (Vec<LaneActivityReport>, ExchangeStats, u64, Duration) {
    let t = Instant::now();
    let mut sim = ShardSim::<u64>::new(fused, plan);
    let stims: Vec<MemberStim<'_>> = designs
        .iter()
        .enumerate()
        .map(|(m, design)| MemberStim { design, activations, seeds: seeds_of(m) })
        .collect();
    let reports = measure_fused_activity(&mut sim, &stims);
    let dt = t.elapsed();
    (reports, sim.exchange_stats(), sim.cycles(), dt)
}

fn streams_per_sec(members: usize, dt: Duration) -> f64 {
    (members * LANES) as f64 / dt.as_secs_f64()
}

fn assert_identical(got: &[LaneActivityReport], want: &[LaneActivityReport], what: &str) {
    for (m, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.cycles, w.cycles, "{what}: member {m} cycle count");
        assert_eq!(g.lanes, w.lanes, "{what}: member {m} per-lane activity");
    }
}

fn main() -> anyhow::Result<()> {
    let activations: u32 = std::env::var("SHARD_BENCH_ACTIVATIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let shards: usize = std::env::var("SHARD_BENCH_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8)
        });
    let require_fused_speedup = std::env::var("SHARD_REQUIRE_FUSED_SPEEDUP")
        .map(|v| v == "1")
        .unwrap_or(false);

    // Compile the whole corpus (parallel, through the FlowSet driver).
    let mut flows = FlowSet::corpus(FlowConfig::default());
    flows
        .run_parallel(|f| f.netlist().map(|_| ()))
        .into_iter()
        .collect::<anyhow::Result<Vec<()>>>()?;
    let mut designs: Vec<PiModuleDesign> = Vec::new();
    let mut mapped = Vec::new();
    for flow in flows.flows_mut() {
        designs.push(flow.rtl()?.clone());
        mapped.push((flow.netlist_fingerprint(), flow.netlist_shared()?));
    }
    let members: Vec<(u64, &Netlist)> =
        mapped.iter().map(|(fp, m)| (*fp, &m.netlist)).collect();
    let n = members.len();

    // Fuse + partition once, outside the timers: the serving path does
    // this at boot and reuses the plan for every round. The artifact
    // carries the refined plan for K=shards.
    let art = ensure_fused(None, &members, shards);
    let plan1 = ShardPlan::partition(&art.fused, 1);
    let plank = &art.plan;
    let nets = art.fused.netlist.len();
    section(&format!(
        "multi-system power throughput — {n} corpus members fused into {nets} nets, \
         {activations} activations x {LANES} lanes each, {shards} shards \
         ({} comb cuts, {} reg cuts; cut cost {} after -{} refinement)",
        plank.cuts.comb_cuts.len(),
        plank.cuts.reg_cuts.len(),
        plank.cut_cost(),
        plank.refinement.removed()
    ));

    // Corpus-wide refinement A/B: at every interesting K (including one
    // past the member count, which forces member splits and hence cut
    // words), the refined plan must never cost more than the PR 7 seed.
    let mut refine_removed_total = 0usize;
    for k in [2, shards.max(2), n + 1] {
        let refined = ShardPlan::partition(&art.fused, k);
        let seed = ShardPlan::partition_unrefined(&art.fused, k);
        assert!(
            refined.cut_cost() <= seed.cut_cost(),
            "K={k}: refined cut cost {} exceeds unrefined {}",
            refined.cut_cost(),
            seed.cut_cost()
        );
        refine_removed_total += refined.refinement.removed();
        println!(
            "partition K={k:<2}        cut cost {:>4} -> {:>4}  ({} moves, {} sweeps)",
            seed.cut_cost(),
            refined.cut_cost(),
            refined.refinement.cluster_moves + refined.refinement.level0_moves,
            refined.refinement.sweeps
        );
    }

    let (reference, per_dt) = per_system_run(&members, &designs, activations);
    let per_sps = streams_per_sec(n, per_dt);
    println!(
        "per-system dispatch   {:>12}  {n} members x {LANES} lanes  -> {per_sps:.2} streams/s",
        fmt_duration(per_dt)
    );

    let (fused1, _, _, f1_dt) = fused_run(&art.fused, &plan1, &designs, activations);
    assert_identical(&fused1, &reference, "fused K=1");
    let f1_sps = streams_per_sec(n, f1_dt);
    println!(
        "fused K=1             {:>12}  one pass, all members          -> {f1_sps:.2} streams/s",
        fmt_duration(f1_dt)
    );

    let (fusedk, k_stats, k_cycles, fk_dt) = fused_run(&art.fused, plank, &designs, activations);
    assert_identical(&fusedk, &reference, "fused sharded");
    let mut fk_sps = streams_per_sec(n, fk_dt);
    println!(
        "fused K={shards} sharded     {:>12}  one pass, {shards} workers           -> {fk_sps:.2} streams/s",
        fmt_duration(fk_dt)
    );
    println!(
        "fused+sharded vs per-system: {:.2}x   vs fused K=1: {:.2}x",
        fk_sps / per_sps,
        fk_sps / f1_sps
    );

    // Dirty-word exchange under guaranteed cuts: one more shard than
    // members forces a member split, so cut words must exist. A full
    // (non-incremental) republication would copy every cut word every
    // cycle; the dirty filter must do strictly less under live LFSR
    // stimulus, while staying bit-identical.
    let plans = ShardPlan::partition(&art.fused, n + 1);
    let (fuseds, s_stats, s_cycles, _) = fused_run(&art.fused, &plans, &designs, activations);
    assert_identical(&fuseds, &reference, "fused split (K=members+1)");
    assert!(s_stats.cut_words > 0, "K={} over {n} members must cut", n + 1);
    let s_full = s_stats.cut_words as u64 * s_cycles;
    let s_pub = s_stats.total_published();
    assert_eq!(s_pub + s_stats.total_skipped(), s_full, "opportunity accounting");
    assert!(
        s_pub < s_full,
        "dirty exchange must publish strictly fewer words than full republication \
         ({s_pub} vs {s_full} over {s_cycles} cycles)"
    );
    println!(
        "dirty exchange K={}    {} cut words: {s_pub}/{s_full} words published \
         ({:.1}% skipped, {:.3} words/cycle)",
        n + 1,
        s_stats.cut_words,
        100.0 * s_stats.total_skipped() as f64 / s_full.max(1) as f64,
        s_pub as f64 / s_cycles.max(1) as f64
    );

    let mut best_per = per_sps;
    if require_fused_speedup && fk_sps <= best_per {
        // One retry before failing: a single timing on a contended
        // shared runner can be noise; the gate's claim is about the
        // dispatch paths, so compare best-of-two.
        let (_, again_per) = per_system_run(&members, &designs, activations);
        let (again_rep, _, _, again_fk) = fused_run(&art.fused, plank, &designs, activations);
        assert_identical(&again_rep, &reference, "fused sharded (retry)");
        best_per = best_per.max(streams_per_sec(n, again_per));
        fk_sps = fk_sps.max(streams_per_sec(n, again_fk));
    }

    write_metrics_json(
        "BENCH_shard.json",
        &[("engine", "shardsim-u64"), ("corpus", "full")],
        &[
            ("members", n as f64),
            ("fused_nets", nets as f64),
            ("activations", activations as f64),
            ("shards", shards as f64),
            ("comb_cuts", plank.cuts.comb_cuts.len() as f64),
            ("reg_cuts", plank.cuts.reg_cuts.len() as f64),
            ("cut_cost_unrefined", plank.refinement.initial_cut_cost as f64),
            ("cut_cost_refined", plank.refinement.refined_cut_cost as f64),
            ("refinement_removed_all_k", refine_removed_total as f64),
            ("cut_words", k_stats.cut_words as f64),
            (
                "words_published_per_cycle",
                k_stats.total_published() as f64 / k_cycles.max(1) as f64,
            ),
            ("split_cut_words", s_stats.cut_words as f64),
            (
                "split_words_published_per_cycle",
                s_pub as f64 / s_cycles.max(1) as f64,
            ),
            (
                "split_publish_ratio",
                s_pub as f64 / s_full.max(1) as f64,
            ),
            ("per_system_streams_per_sec", per_sps),
            ("fused_k1_streams_per_sec", f1_sps),
            ("fused_sharded_streams_per_sec", fk_sps),
            ("fused_sharded_vs_per_system", fk_sps / per_sps),
            ("fused_sharded_vs_k1", fk_sps / f1_sps),
        ],
    )?;
    println!("wrote BENCH_shard.json");

    if require_fused_speedup {
        anyhow::ensure!(
            fk_sps > best_per,
            "fused+sharded dispatch must strictly beat per-system streams/sec \
             (best-of-two: {fk_sps:.2} vs {best_per:.2}, K={shards})"
        );
        println!(
            "fused-speedup gate passed: {:.2}x streams/sec over per-system dispatch",
            fk_sps / best_per
        );
    }
    Ok(())
}
