//! Gate-level simulator throughput: scalar `GateSim` vs the bit-parallel
//! 64-lane `WordSim`, on the largest corpus netlist, under the same
//! power-analysis LFSR stimulus. Emits `BENCH_gatesim.json` so CI can
//! track the perf trajectory (simulated cycles × lanes per wall-second).
//!
//! Needs no artifacts — this is the pure synthesis/power path.
//!
//! ```text
//! cargo bench --bench gatesim
//! GATESIM_BENCH_ACTIVATIONS=2000 cargo bench --bench gatesim
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::flow::{FlowConfig, FlowSet};
use dimsynth::power;
use dimsynth::stim::LfsrBank64;
use dimsynth::synth::LANES;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let activations: u32 = std::env::var("GATESIM_BENCH_ACTIVATIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);

    // Largest corpus netlist = the throughput-critical case. The whole
    // corpus synthesizes in parallel through the FlowSet driver.
    let mut flows = FlowSet::corpus(FlowConfig::default());
    let sizes: Vec<usize> = flows
        .run_parallel(|f| f.netlist().map(|m| m.netlist.len()))
        .into_iter()
        .collect::<anyhow::Result<_>>()?;
    let biggest = sizes
        .iter()
        .enumerate()
        .max_by_key(|(_, &n)| n)
        .map(|(i, _)| i)
        .expect("corpus is non-empty");
    let flow = &mut flows.flows_mut()[biggest];
    let id = flow.id().to_string();
    let design = flow.rtl()?.clone();
    let mapped = flow.netlist()?;
    let nets = mapped.netlist.len();
    section(&format!(
        "gate-level sim throughput — {id} ({nets} nets, {} LUTs, {} DFFs, {activations} activations)",
        mapped.luts, mapped.dffs
    ));

    // Scalar baseline (the reference oracle), lane 0's stimulus.
    let seeds = LfsrBank64::lane_seeds(0xACE1);
    let t = Instant::now();
    let scalar_act = power::measure_activity(&mapped.netlist, &design, activations, seeds[0]);
    let scalar_dt = t.elapsed();
    let scalar_cps = scalar_act.cycles as f64 / scalar_dt.as_secs_f64();
    println!(
        "scalar GateSim      {:>12}  {} cycles  -> {:.3} Mcycles/s",
        fmt_duration(scalar_dt),
        scalar_act.cycles,
        scalar_cps / 1e6
    );

    // Word-parallel engine: 64 independent streams in one pass.
    let t = Instant::now();
    let word_act = power::measure_activity_batch(&mapped.netlist, &design, activations, &seeds);
    let word_dt = t.elapsed();
    let word_cps = word_act.cycles as f64 / word_dt.as_secs_f64();
    let word_lane_cps = word_cps * LANES as f64;
    println!(
        "word-parallel (64)  {:>12}  {} cycles x {LANES} lanes  -> {:.3} Mlane-cycles/s",
        fmt_duration(word_dt),
        word_act.cycles,
        word_lane_cps / 1e6
    );

    let speedup = word_lane_cps / scalar_cps;
    println!(
        "speedup: {speedup:.1}x (activity mean {:.1} toggles/cycle, spread {:.2})",
        word_act.mean(),
        word_act.spread()
    );

    // Raw free-running LFSR bitstream stimulus (the paper's "pseudorandom
    // signal input stream"), driven word-parallel from `LfsrBank64`: one
    // independent 64-lane bitstream per input-bus bit, no start/done
    // protocol — the pure netlist-throughput figure.
    let raw_cycles: u64 = 64 * activations as u64;
    let mut banks: Vec<Vec<LfsrBank64>> = mapped
        .netlist
        .input_buses
        .iter()
        .enumerate()
        .map(|(bi, (_, bits))| {
            (0..bits.len())
                .map(|k| LfsrBank64::new(0xB175_EED ^ (bi * 131 + k) as u32))
                .collect()
        })
        .collect();
    let bus_names: Vec<String> =
        mapped.netlist.input_buses.iter().map(|(n, _)| n.clone()).collect();
    let t = Instant::now();
    let mut wsim = dimsynth::synth::WordSim::new(&mapped.netlist);
    for _ in 0..raw_cycles {
        for (bi, name) in bus_names.iter().enumerate() {
            let mut vals = [0i64; LANES];
            for (k, bank) in banks[bi].iter_mut().enumerate() {
                let word = bank.next_bit_word();
                for (lane, v) in vals.iter_mut().enumerate() {
                    *v |= ((word >> lane & 1) as i64) << k;
                }
            }
            wsim.set_bus_lanes(name, &vals);
        }
        wsim.step();
    }
    let raw_dt = t.elapsed();
    let raw_lane_cps = raw_cycles as f64 * LANES as f64 / raw_dt.as_secs_f64();
    println!(
        "raw bitstream (64)  {:>12}  {raw_cycles} cycles x {LANES} lanes  -> {:.3} Mlane-cycles/s",
        fmt_duration(raw_dt),
        raw_lane_cps / 1e6
    );

    write_metrics_json(
        "BENCH_gatesim.json",
        &[("design", &id), ("engine", "wordsim-64")],
        &[
            ("nets", nets as f64),
            ("luts", mapped.luts as f64),
            ("dffs", mapped.dffs as f64),
            ("activations", activations as f64),
            ("scalar_cycles_per_sec", scalar_cps),
            ("word_cycles_per_sec", word_cps),
            ("word_lane_cycles_per_sec", word_lane_cps),
            ("raw_bitstream_lane_cycles_per_sec", raw_lane_cps),
            ("speedup", speedup),
            ("toggles_per_cycle_mean", word_act.mean()),
            ("toggles_per_cycle_spread", word_act.spread()),
        ],
    )?;
    println!("wrote BENCH_gatesim.json");
    Ok(())
}
