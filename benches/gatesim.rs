//! Gate-level simulator throughput: scalar `GateSim` vs the bit-parallel
//! `WordSim` at 64 and 256 lanes, plus the intra-level parallel mode, on
//! the largest corpus netlist, under the same power-analysis LFSR
//! stimulus. Emits `BENCH_gatesim.json` so CI can track the perf
//! trajectory (simulated cycles × lanes per wall-second, and stimulus
//! streams per wall-second per engine).
//!
//! Needs no artifacts — this is the pure synthesis/power path.
//!
//! ```text
//! cargo bench --bench gatesim
//! GATESIM_BENCH_ACTIVATIONS=2000 cargo bench --bench gatesim
//! GATESIM_REQUIRE_WIDE_SPEEDUP=1 cargo bench --bench gatesim   # CI gate:
//! #   fails unless 256-lane streams/sec strictly beats 64-lane
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::flow::{FlowConfig, FlowSet};
use dimsynth::power::{self, LaneActivityReport};
use dimsynth::stim::{LfsrBank, LfsrBank64};
use dimsynth::synth::{LaneWord, LANES, LEVEL_PAR_THRESHOLD, W256};
use std::time::{Duration, Instant};

/// One timed batched-measurement run.
struct Series {
    act: LaneActivityReport,
    dt: Duration,
    lanes: usize,
}

impl Series {
    fn lane_cps(&self) -> f64 {
        self.act.cycles as f64 * self.lanes as f64 / self.dt.as_secs_f64()
    }

    /// Independent stimulus streams fully simulated per wall-second.
    fn streams_per_sec(&self) -> f64 {
        self.lanes as f64 / self.dt.as_secs_f64()
    }
}

fn run_series<W: LaneWord>(
    netlist: &dimsynth::synth::Netlist,
    design: &dimsynth::rtl::PiModuleDesign,
    activations: u32,
    seeds: &[u32],
    par: Option<usize>,
) -> Series {
    let t = Instant::now();
    let act =
        power::measure_activity_batch_wide::<W>(netlist, design, activations, seeds, par);
    Series { act, dt: t.elapsed(), lanes: W::LANES }
}

fn main() -> anyhow::Result<()> {
    let activations: u32 = std::env::var("GATESIM_BENCH_ACTIVATIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);
    let require_wide_speedup = std::env::var("GATESIM_REQUIRE_WIDE_SPEEDUP")
        .map(|v| v == "1")
        .unwrap_or(false);

    // Largest corpus netlist = the throughput-critical case. The whole
    // corpus synthesizes in parallel through the FlowSet driver.
    let mut flows = FlowSet::corpus(FlowConfig::default());
    let sizes: Vec<usize> = flows
        .run_parallel(|f| f.netlist().map(|m| m.netlist.len()))
        .into_iter()
        .collect::<anyhow::Result<_>>()?;
    let biggest = sizes
        .iter()
        .enumerate()
        .max_by_key(|(_, &n)| n)
        .map(|(i, _)| i)
        .expect("corpus is non-empty");
    let flow = &mut flows.flows_mut()[biggest];
    let id = flow.id().to_string();
    let design = flow.rtl()?.clone();
    let mapped = flow.netlist()?;
    let nets = mapped.netlist.len();
    section(&format!(
        "gate-level sim throughput — {id} ({nets} nets, {} LUTs, {} DFFs, {activations} activations)",
        mapped.luts, mapped.dffs
    ));

    // Scalar baseline (the reference oracle), lane 0's stimulus.
    let seeds256 = LfsrBank::<W256>::lane_seeds(0xACE1);
    let seeds64 = &seeds256[..LANES];
    let t = Instant::now();
    let scalar_act = power::measure_activity(&mapped.netlist, &design, activations, seeds64[0]);
    let scalar_dt = t.elapsed();
    let scalar_cps = scalar_act.cycles as f64 / scalar_dt.as_secs_f64();
    println!(
        "scalar GateSim        {:>12}  {} cycles  -> {:.3} Mcycles/s",
        fmt_duration(scalar_dt),
        scalar_act.cycles,
        scalar_cps / 1e6
    );

    // Word-parallel engines: 64 vs 256 independent streams per pass.
    let w64 = run_series::<u64>(&mapped.netlist, &design, activations, seeds64, None);
    println!(
        "word-parallel (64)    {:>12}  {} cycles x {} lanes  -> {:.3} Mlane-cycles/s, {:.2} streams/s",
        fmt_duration(w64.dt),
        w64.act.cycles,
        w64.lanes,
        w64.lane_cps() / 1e6,
        w64.streams_per_sec()
    );
    let w256 = run_series::<W256>(&mapped.netlist, &design, activations, &seeds256, None);
    println!(
        "word-parallel (256)   {:>12}  {} cycles x {} lanes  -> {:.3} Mlane-cycles/s, {:.2} streams/s",
        fmt_duration(w256.dt),
        w256.act.cycles,
        w256.lanes,
        w256.lane_cps() / 1e6,
        w256.streams_per_sec()
    );
    let speedup64 = w64.lane_cps() / scalar_cps;
    let wide_speedup = w256.streams_per_sec() / w64.streams_per_sec();
    println!(
        "64-lane vs scalar: {speedup64:.1}x   256-lane vs 64-lane streams/s: {wide_speedup:.2}x"
    );

    // Sanity: the two widths measure identical physics on the shared
    // seed prefix (lane l depends only on seed l).
    assert_eq!(w64.act.cycles, w256.act.cycles, "widths disagreed on cycle count");
    assert_eq!(
        &w256.act.lanes[..LANES],
        &w64.act.lanes[..],
        "widths disagreed on per-lane activity"
    );

    // Intra-level parallel mode, at both widths; results must be
    // bit-identical to the sequential engines.
    let w64p = run_series::<u64>(
        &mapped.netlist,
        &design,
        activations,
        seeds64,
        Some(LEVEL_PAR_THRESHOLD),
    );
    let w256p = run_series::<W256>(
        &mapped.netlist,
        &design,
        activations,
        &seeds256,
        Some(LEVEL_PAR_THRESHOLD),
    );
    assert_eq!(w64p.act.lanes, w64.act.lanes, "parallel != sequential (64)");
    assert_eq!(w256p.act.lanes, w256.act.lanes, "parallel != sequential (256)");
    println!(
        "intra-level parallel  64: {:.3} Mlane-cycles/s ({:.2}x seq)   256: {:.3} Mlane-cycles/s ({:.2}x seq)",
        w64p.lane_cps() / 1e6,
        w64p.lane_cps() / w64.lane_cps(),
        w256p.lane_cps() / 1e6,
        w256p.lane_cps() / w256.lane_cps()
    );

    // Raw free-running LFSR bitstream stimulus (the paper's "pseudorandom
    // signal input stream"), driven word-parallel from `LfsrBank64`: one
    // independent 64-lane bitstream per input-bus bit, no start/done
    // protocol — the pure netlist-throughput figure.
    let raw_cycles: u64 = 64 * activations as u64;
    let mut banks: Vec<Vec<LfsrBank64>> = mapped
        .netlist
        .input_buses
        .iter()
        .enumerate()
        .map(|(bi, (_, bits))| {
            (0..bits.len())
                .map(|k| LfsrBank64::new(0xB175_EED ^ (bi * 131 + k) as u32))
                .collect()
        })
        .collect();
    let bus_names: Vec<String> =
        mapped.netlist.input_buses.iter().map(|(n, _)| n.clone()).collect();
    let t = Instant::now();
    let mut wsim = dimsynth::synth::WordSim::<u64>::new(&mapped.netlist);
    for _ in 0..raw_cycles {
        for (bi, name) in bus_names.iter().enumerate() {
            let mut vals = [0i64; LANES];
            for (k, bank) in banks[bi].iter_mut().enumerate() {
                let word = bank.next_bit_word();
                for (lane, v) in vals.iter_mut().enumerate() {
                    *v |= ((word >> lane & 1) as i64) << k;
                }
            }
            wsim.set_bus_lanes(name, &vals);
        }
        wsim.step();
    }
    let raw_dt = t.elapsed();
    let raw_lane_cps = raw_cycles as f64 * LANES as f64 / raw_dt.as_secs_f64();
    println!(
        "raw bitstream (64)    {:>12}  {raw_cycles} cycles x {LANES} lanes  -> {:.3} Mlane-cycles/s",
        fmt_duration(raw_dt),
        raw_lane_cps / 1e6
    );

    write_metrics_json(
        "BENCH_gatesim.json",
        &[("design", &id), ("engine", "wordsim-generic")],
        &[
            ("nets", nets as f64),
            ("luts", mapped.luts as f64),
            ("dffs", mapped.dffs as f64),
            ("activations", activations as f64),
            ("scalar_cycles_per_sec", scalar_cps),
            ("word_cycles_per_sec", w64.act.cycles as f64 / w64.dt.as_secs_f64()),
            ("word_lane_cycles_per_sec", w64.lane_cps()),
            ("word_streams_per_sec", w64.streams_per_sec()),
            ("word256_lane_cycles_per_sec", w256.lane_cps()),
            ("word256_streams_per_sec", w256.streams_per_sec()),
            ("speedup", speedup64),
            ("speedup_256_vs_64_streams", wide_speedup),
            ("word_par_lane_cycles_per_sec", w64p.lane_cps()),
            ("word256_par_lane_cycles_per_sec", w256p.lane_cps()),
            ("par_speedup_64", w64p.lane_cps() / w64.lane_cps()),
            ("par_speedup_256", w256p.lane_cps() / w256.lane_cps()),
            ("raw_bitstream_lane_cycles_per_sec", raw_lane_cps),
            ("toggles_per_cycle_mean", w64.act.mean()),
            ("toggles_per_cycle_spread", w64.act.spread()),
        ],
    )?;
    println!("wrote BENCH_gatesim.json");

    if require_wide_speedup {
        let mut best_256 = w256.streams_per_sec();
        let mut best_64 = w64.streams_per_sec();
        if best_256 <= best_64 {
            // One retry before failing: a single timing on a contended
            // shared runner can be noise; the gate's claim is about the
            // engines, so compare best-of-two.
            let again64 =
                run_series::<u64>(&mapped.netlist, &design, activations, seeds64, None);
            let again256 =
                run_series::<W256>(&mapped.netlist, &design, activations, &seeds256, None);
            best_64 = best_64.max(again64.streams_per_sec());
            best_256 = best_256.max(again256.streams_per_sec());
        }
        anyhow::ensure!(
            best_256 > best_64,
            "256-lane engine must strictly beat 64-lane streams/sec \
             (best-of-two: {best_256:.2} vs {best_64:.2} on {id})"
        );
        println!(
            "wide-speedup gate passed: {:.2}x streams/sec at 256 lanes",
            best_256 / best_64
        );
    }
    Ok(())
}
