//! Bench P1 (DESIGN.md §4): Π-path throughput — the three bit-identical
//! Π implementations (native fixed point, AOT Pallas kernel via PJRT,
//! cycle-accurate RTL simulation) across batch sizes, plus end-to-end
//! coordinator throughput.
//!
//! Requires `make artifacts`.
//!
//! ```text
//! cargo bench --bench pi_throughput
//! ```

use dimsynth::bench_util::{bench_auto, section};
use dimsynth::fixedpoint::{self, Q16_15};
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::report::export::export_from_flow;
use dimsynth::rtl;
use dimsynth::runtime::{engine, Engine};
use dimsynth::stim::Lfsr32;
use std::time::Duration;

const SYSTEM: &str = "unpowered_flight";

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut flow = Flow::for_system(SYSTEM, FlowConfig::default())?;
    let export = export_from_flow(&mut flow)?;
    let design = flow.rtl()?.clone();
    let cycles = flow.latency()?;
    let kp = export.ports.len();

    let mut rng = Lfsr32::new(0xF00D);
    let batch: Vec<Vec<i64>> = (0..64)
        .map(|_| (0..kp).map(|_| Q16_15.from_f64(rng.range(0.25, 8.0))).collect())
        .collect();
    let budget = Duration::from_millis(500);

    section(&format!("Π computation paths — {SYSTEM} (batch of 64)"));
    let r = bench_auto("native fixed point (64 samples)", budget, || {
        for s in &batch {
            for exps in &export.exponents {
                std::hint::black_box(fixedpoint::eval_monomial(Q16_15, s, exps));
            }
        }
    });
    println!("{r}   → {:.2} Msamples/s", 64.0 * r.per_sec() / 1e6);

    let mut eng = Engine::new("artifacts")?;
    let pi1 = eng.load(&format!("pi_{SYSTEM}_b1"))?;
    let pi64 = eng.load(&format!("pi_{SYSTEM}_b64"))?;
    let flat: Vec<i64> = batch.iter().flatten().copied().collect();
    let lit64 = engine::i32_matrix(64, kp, &flat)?;
    let r = bench_auto("pallas/PJRT b=64 (64 samples)", budget, || {
        std::hint::black_box(pi64.run(std::slice::from_ref(&lit64)).unwrap());
    });
    println!("{r}   → {:.2} ksamples/s", 64.0 * r.per_sec() / 1e3);
    let lit1 = engine::i32_matrix(1, kp, &batch[0])?;
    let r = bench_auto("pallas/PJRT b=1  (1 sample)", budget, || {
        std::hint::black_box(pi1.run(std::slice::from_ref(&lit1)).unwrap());
    });
    println!("{r}   → {:.2} ksamples/s", r.per_sec() / 1e3);

    let r = bench_auto("rtl cycle-accurate sim (1 sample)", budget, || {
        std::hint::black_box(rtl::run_once(&design, &batch[0]));
    });
    println!(
        "{r}   → {:.1} ksamples/s ({:.1} Mcycles/s simulated)",
        r.per_sec() / 1e3,
        cycles as f64 * r.per_sec() / 1e6
    );

    section("gate-level sim (power-analysis path)");
    let mapped = flow.netlist()?;
    let r = bench_auto("scalar GateSim (1 activation)", Duration::from_millis(800), || {
        let mut sim = dimsynth::synth::GateSim::new(&mapped.netlist);
        for (p, v) in design.ports.iter().zip(&batch[0]) {
            sim.set_bus(&format!("in_{}", p.name), *v);
        }
        sim.set_bus("start", 1);
        sim.step();
        sim.set_bus("start", 0);
        while !sim.get_bit("done") {
            sim.step();
        }
    });
    println!(
        "{r}   → {:.2} Mcell-cycles/s",
        (mapped.luts + mapped.dffs) as f64 * cycles as f64 * r.per_sec() / 1e6
    );

    // Word-parallel engine: 64 independent activations per pass.
    let seeds = dimsynth::stim::LfsrBank64::lane_seeds(0xF00D);
    let r64 = bench_auto(
        "word-parallel WordSim (64 lanes, 1 activation each)",
        Duration::from_millis(800),
        || {
            std::hint::black_box(dimsynth::power::measure_activity_batch(
                &mapped.netlist,
                &design,
                1,
                &seeds,
            ));
        },
    );
    let lanes = dimsynth::synth::LANES as f64;
    println!(
        "{r64}   → {:.2} Mcell-cycles/s ({:.1}x scalar activation throughput)",
        lanes * (mapped.luts + mapped.dffs) as f64 * cycles as f64 * r64.per_sec() / 1e6,
        lanes * r64.per_sec() / r.per_sec()
    );
    Ok(())
}
