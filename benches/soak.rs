//! Soak bench: replay ~1M mixed Π/power requests from concurrent
//! tenants — two steady streams, one flooder, one light tenant —
//! through the real TCP serving stack (net → admission → two dispatch
//! lanes) on one warm [`ServeSet`], and gate the things a soak exists
//! to catch: tail-latency collapse and starvation. Emits
//! `BENCH_soak.json`. (`benches/dispatch.rs` sweeps the lane count.)
//!
//! Always asserted, any size: every request gets exactly one typed
//! answer, the flooder is shed (not hung), the light tenant sees zero
//! shed (no starvation under trivial load), and graceful drain leaves
//! `terminal == admitted` for every tenant.
//!
//! ```text
//! cargo bench --bench soak                      # full ~1M-request soak
//! SOAK_REQUESTS=20000 cargo bench --bench soak  # scaled-down smoke
//! SOAK_REQUIRE_TAIL=1 ...                       # also gate steady p99
//! SOAK_P99_BUDGET_US=2000000 ...                # custom p99 budget
//! ```

use dimsynth::bench_util::{fmt_duration, section, write_metrics_json};
use dimsynth::coordinator::net::run_driver;
use dimsynth::coordinator::{
    AdmissionConfig, DriverConfig, DriverReport, EngineConfig, FaultPlan, NetServer,
    ServeSet, TenantSpec, TrafficEngine,
};
use dimsynth::flow::FlowConfig;
use dimsynth::synth::LaneWidth;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let total = env_u64("SOAK_REQUESTS", 1_000_000) as usize;
    let require_tail = std::env::var("SOAK_REQUIRE_TAIL").is_ok_and(|v| v == "1");
    let p99_budget_us = env_u64("SOAK_P99_BUDGET_US", 2_000_000);

    // Light tenant stays light at every scale; the flooder offers ~20%
    // of traffic against a rate limit sized to shed most of it; the two
    // steady tenants split the rest.
    let light_n = (total / 50).clamp(20, 2_000);
    let flood_n = total / 5;
    let steady_n = (total - flood_n - light_n) / 2;

    section(&format!(
        "soak: {total} requests over TCP (2 steady + flood + light tenants)"
    ));

    let config = FlowConfig {
        power_samples: 2,
        lane_width: LaneWidth::W64,
        ..FlowConfig::default()
    };
    let set = ServeSet::boot(&["pendulum", "spring_mass"], config, None)?;
    let pendulum_ports = set.handle_at(0).design().num_inputs();
    let spring_ports = set.handle_at(1).design().num_inputs();

    let admission = AdmissionConfig {
        tenants: vec![
            TenantSpec::new("steady-a", "pendulum").with_queue_cap(4096),
            TenantSpec::new("steady-b", "spring_mass").with_queue_cap(4096),
            TenantSpec::new("flood", "spring_mass")
                .with_rate(500.0, 32.0)
                .with_queue_cap(64),
            TenantSpec::new("light", "pendulum").with_queue_cap(4096),
        ],
        default_deadline: Duration::from_secs(60),
    };
    let engine = Arc::new(TrafficEngine::start(
        &set,
        admission,
        EngineConfig { activations: 2, max_batch: 0, dispatchers: 2 },
        FaultPlan::none(),
    )?);
    let server = NetServer::start(engine, "127.0.0.1:0")?;
    let addr = server.local_addr().to_string();

    let drivers = vec![
        DriverConfig {
            requests: steady_n,
            window: 64,
            seed: 0x50A0 ^ 0xA,
            power_ratio: 0.05,
            ..DriverConfig::new("steady-a", pendulum_ports)
        },
        DriverConfig {
            requests: steady_n,
            window: 64,
            seed: 0x50A0 ^ 0xB,
            power_ratio: 0.05,
            ..DriverConfig::new("steady-b", spring_ports)
        },
        DriverConfig {
            requests: flood_n,
            window: 128,
            seed: 0x50A0 ^ 0xC,
            power_ratio: 0.05,
            ..DriverConfig::new("flood", spring_ports)
        },
        // Trickled requests: a tenant this light must never be shed or
        // starved no matter what its neighbours do.
        DriverConfig {
            requests: light_n,
            window: 1,
            seed: 0x50A0 ^ 0xD,
            power_ratio: 0.0,
            gap: Duration::from_micros(200),
            ..DriverConfig::new("light", pendulum_ports)
        },
    ];

    let t = Instant::now();
    let joins: Vec<_> = drivers
        .into_iter()
        .map(|cfg| {
            let addr = addr.clone();
            std::thread::spawn(move || (cfg.tenant.clone(), run_driver(&addr, &cfg).unwrap()))
        })
        .collect();
    let mut reports = std::collections::HashMap::<String, DriverReport>::new();
    for j in joins {
        let (tenant, report) = j.join().expect("driver thread");
        reports.insert(tenant, report);
    }
    let wall = t.elapsed().max(Duration::from_nanos(1));

    let sent: u64 = reports.values().map(|r| r.sent).sum();
    let rps = sent as f64 / wall.as_secs_f64();
    println!("replayed {sent} requests in {} ({rps:.0} req/s)", fmt_duration(wall));
    for name in ["steady-a", "steady-b", "flood", "light"] {
        let r = &reports[name];
        println!(
            "{name:<9} sent {:>8}  ok {:>8}  shed {:>8}  µs p50 {:>7} p99 {:>7} p999 {:>7}",
            r.sent,
            r.ok,
            r.shed,
            r.latency.percentile_us(0.50),
            r.latency.percentile_us(0.99),
            r.latency.percentile_us(0.999),
        );
    }

    // -- invariants that hold at every soak size -----------------------
    for (name, r) in &reports {
        assert_eq!(r.answered(), r.sent, "{name}: a request went unanswered: {r:?}");
        assert_eq!(r.panicked + r.protocol + r.tenant_unknown, 0, "{name}: {r:?}");
    }
    let flood = &reports["flood"];
    assert!(flood.shed > 0, "flood must be shed, not absorbed: {flood:?}");
    let light = &reports["light"];
    assert_eq!(light.shed, 0, "light tenant must never be shed: {light:?}");
    assert_eq!(light.ok, light.sent, "light tenant must be fully served: {light:?}");
    for name in ["steady-a", "steady-b"] {
        let r = &reports[name];
        assert_eq!(r.ok, r.sent, "{name} is self-clocked, nothing may shed: {r:?}");
    }

    let report = server.shutdown();
    assert!(!report.engine_panicked);
    for t in &report.tenants {
        assert_eq!(
            t.counters.terminal(),
            t.counters.admitted,
            "tenant `{}` drained dirty: {:?}",
            t.tenant,
            t.counters
        );
        assert_eq!(t.queue_depth, 0, "tenant `{}` queue not drained", t.tenant);
    }

    // -- tail gates (opt-in: wall-clock on shared runners is noisy) ----
    let steady_p99 = ["steady-a", "steady-b"]
        .iter()
        .map(|n| reports[*n].latency.percentile_us(0.99))
        .max()
        .unwrap_or(0);
    let light_p99 = light.latency.percentile_us(0.99);
    if require_tail {
        assert!(
            steady_p99 <= p99_budget_us,
            "steady p99 {steady_p99} µs blew the {p99_budget_us} µs budget"
        );
        assert!(
            light_p99 <= p99_budget_us,
            "light p99 {light_p99} µs blew the {p99_budget_us} µs budget"
        );
        println!("tail gate: p99 {steady_p99} µs (steady) / {light_p99} µs (light) within {p99_budget_us} µs");
    }

    write_metrics_json(
        "BENCH_soak.json",
        &[("driver", "net-soak"), ("systems", "pendulum+spring_mass")],
        &[
            ("requests", sent as f64),
            ("wall_s", wall.as_secs_f64()),
            ("req_per_s", rps),
            ("steady_p50_us", reports["steady-a"].latency.percentile_us(0.50) as f64),
            ("steady_p99_us", steady_p99 as f64),
            ("steady_p999_us", ["steady-a", "steady-b"]
                .iter()
                .map(|n| reports[*n].latency.percentile_us(0.999))
                .max()
                .unwrap_or(0) as f64),
            ("light_p99_us", light_p99 as f64),
            ("flood_shed", flood.shed as f64),
            ("flood_served", flood.ok as f64),
            ("light_shed", light.shed as f64),
            ("tail_gated", if require_tail { 1.0 } else { 0.0 }),
            ("p99_budget_us", p99_budget_us as f64),
        ],
    )?;
    println!("wrote BENCH_soak.json");
    Ok(())
}
