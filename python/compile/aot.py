"""AOT pipeline: lower the Layer-1/Layer-2 computations to HLO text for
the Rust PJRT runtime.

Inputs:  artifacts/pisearch.json — the Π-search interchange emitted by
         `dimsynth export-pisearch` (single source of truth for exponent
         matrices; see rust/src/report/export.rs).
Outputs: artifacts/<name>.hlo.txt per computation:

    pi_<id>_b{1,64}        quantized signals -> Π products (Pallas kernel)
    phi_infer_<id>_b{1,64} Π features -> prediction (Φ model)
    phi_train_<id>         one SGD step on Π features
    raw_infer_<id>_b64     raw-signal baseline inference
    raw_train_<id>         raw-signal baseline SGD step
    pipeline_<id>_b64      fused: quantized signals -> Π -> prediction

HLO *text* is the interchange format: jax ≥ 0.5 serializes HloModuleProto
with 64-bit instruction ids, which xla_extension 0.5.1 (the version the
`xla` crate binds) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)  # int64 lanes in the Π kernel

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .kernels.pi_kernel import pi_products  # noqa: E402
from . import model  # noqa: E402

TRAIN_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(out_dir: str, name: str, text: str, manifest: list):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append(name)
    print(f"  wrote {path} ({len(text)} chars)")


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_system(sys_desc: dict, out_dir: str, manifest: list):
    sid = sys_desc["id"]
    exps = tuple(tuple(row) for row in sys_desc["exponents"])
    kp = len(sys_desc["ports"])  # participating signals (hardware ports)
    k = len(sys_desc["symbols"])  # all signals (raw baseline)
    n = len(exps)
    pi_in_dim = max(n - 1, 1)
    raw_in_dim = k - 1
    f32 = jnp.float32
    i32 = jnp.int32
    print(f"[{sid}] k={k} ports={kp} N={n}")

    # --- Π kernels -----------------------------------------------------------
    for b in (1, 64):
        def pi_fn(x, _exps=exps, _b=b):
            return (pi_products(x, _exps, block_b=min(64, _b)),)

        lowered = jax.jit(pi_fn).lower(spec((b, kp), i32))
        write(out_dir, f"pi_{sid}_b{b}", to_hlo_text(lowered), manifest)

    # --- Φ model over Π features ----------------------------------------------
    p_pi = model.param_count(pi_in_dim)
    for b in (1, 64):
        def infer_fn(params, x, shift, scale, _d=pi_in_dim):
            return (model.infer(params, x, shift, scale, _d),)

        lowered = jax.jit(infer_fn).lower(
            spec((p_pi,), f32), spec((b, pi_in_dim), f32),
            spec((pi_in_dim,), f32), spec((pi_in_dim,), f32),
        )
        write(out_dir, f"phi_infer_{sid}_b{b}", to_hlo_text(lowered), manifest)

    def train_fn(params, x, y, shift, scale, lr, _d=pi_in_dim):
        return model.train_step(params, x, y, shift, scale, lr, _d)

    lowered = jax.jit(train_fn).lower(
        spec((p_pi,), f32), spec((TRAIN_BATCH, pi_in_dim), f32),
        spec((TRAIN_BATCH,), f32), spec((pi_in_dim,), f32),
        spec((pi_in_dim,), f32), spec((), f32),
    )
    write(out_dir, f"phi_train_{sid}", to_hlo_text(lowered), manifest)

    # --- raw-signal baseline ----------------------------------------------------
    p_raw = model.param_count(raw_in_dim)

    def raw_infer_fn(params, x, shift, scale, _d=raw_in_dim):
        return (model.infer(params, x, shift, scale, _d),)

    lowered = jax.jit(raw_infer_fn).lower(
        spec((p_raw,), f32), spec((64, raw_in_dim), f32),
        spec((raw_in_dim,), f32), spec((raw_in_dim,), f32),
    )
    write(out_dir, f"raw_infer_{sid}_b64", to_hlo_text(lowered), manifest)

    def raw_train_fn(params, x, y, shift, scale, lr, _d=raw_in_dim):
        return model.train_step(params, x, y, shift, scale, lr, _d)

    lowered = jax.jit(raw_train_fn).lower(
        spec((p_raw,), f32), spec((TRAIN_BATCH, raw_in_dim), f32),
        spec((TRAIN_BATCH,), f32), spec((raw_in_dim,), f32),
        spec((raw_in_dim,), f32), spec((), f32),
    )
    write(out_dir, f"raw_train_{sid}", to_hlo_text(lowered), manifest)

    # --- fused pipeline (Fig. 3): quantized signals -> Π -> prediction ---------
    def pipeline_fn(params, x_q, shift, scale, _exps=exps):
        return (model.pi_then_infer(params, x_q, shift, scale, _exps),)

    lowered = jax.jit(pipeline_fn).lower(
        spec((p_pi,), f32), spec((64, kp), i32),
        spec((pi_in_dim,), f32), spec((pi_in_dim,), f32),
    )
    write(out_dir, f"pipeline_{sid}_b64", to_hlo_text(lowered), manifest)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pisearch", default="../artifacts/pisearch.json")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--systems", default="", help="comma list; default all")
    args = ap.parse_args()

    with open(args.pisearch) as f:
        desc = json.load(f)
    assert desc["format"]["frac_bits"] == 15, "artifacts assume Q16.15"
    os.makedirs(args.out, exist_ok=True)

    only = {s for s in args.systems.split(",") if s}
    manifest = []
    for sys_desc in desc["systems"]:
        if only and sys_desc["id"] not in only:
            continue
        lower_system(sys_desc, args.out, manifest)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"{len(manifest)} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
