"""Layer-2 JAX model: the dimensional-function-synthesis calibration model
Φ and its training step, plus the raw-signal baseline (Wang et al. [5]).

The Φ model is a small MLP trained to predict the target dimensionless
product Π₀ from the remaining products Π₁…Π_{N−1} (for N = 1 systems the
input degenerates to a constant feature and the model learns the constant
of proportionality, e.g. 4π² for the pendulum). The baseline predicts the
raw target signal from the remaining raw signals — the comparison the
paper's speedup/accuracy claims rest on.

All functions here are *build-time only*: `aot.py` lowers them to HLO text
once; the Rust runtime loads and executes the artifacts. Parameters
travel as a single flat f32 vector so the Rust side needs no pytree
knowledge.

Layout of the flat parameter vector for `in_dim -> H -> H -> 1`:
    [W1 (in_dim*H), b1 (H), W2 (H*H), b2 (H), W3 (H), b3 (1)]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.pi_kernel import pi_products

HIDDEN = 16


def param_count(in_dim: int, hidden: int = HIDDEN) -> int:
    return in_dim * hidden + hidden + hidden * hidden + hidden + hidden + 1


def init_params(key, in_dim: int, hidden: int = HIDDEN):
    """Glorot-ish init, flattened."""
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.normal(k1, (in_dim, hidden)) * (1.0 / max(in_dim, 1)) ** 0.5
    w2 = jax.random.normal(k2, (hidden, hidden)) * (1.0 / hidden) ** 0.5
    w3 = jax.random.normal(k3, (hidden,)) * (1.0 / hidden) ** 0.5
    return jnp.concatenate(
        [
            w1.reshape(-1),
            jnp.zeros(hidden),
            w2.reshape(-1),
            jnp.zeros(hidden),
            w3,
            jnp.zeros(1),
        ]
    ).astype(jnp.float32)


def _unflatten(params, in_dim: int, hidden: int = HIDDEN):
    o = 0
    w1 = params[o : o + in_dim * hidden].reshape(in_dim, hidden)
    o += in_dim * hidden
    b1 = params[o : o + hidden]
    o += hidden
    w2 = params[o : o + hidden * hidden].reshape(hidden, hidden)
    o += hidden * hidden
    b2 = params[o : o + hidden]
    o += hidden
    w3 = params[o : o + hidden]
    o += hidden
    b3 = params[o]
    return w1, b1, w2, b2, w3, b3


def mlp_forward(params, x, in_dim: int, hidden: int = HIDDEN):
    """MLP over standardized features. x: [B, in_dim] -> [B]."""
    w1, b1, w2, b2, w3, b3 = _unflatten(params, in_dim, hidden)
    h = jnp.tanh(x @ w1 + b1)
    h = jnp.tanh(h @ w2 + b2)
    return h @ w3 + b3


def infer(params, x, shift, scale, in_dim: int):
    """Inference entry point lowered by aot.py.

    Args:
      params: [P] f32 flat parameters.
      x: [B, in_dim] f32 raw features.
      shift/scale: [in_dim] f32 feature standardization (computed by the
        trainer on the training set and shipped with the parameters).
    Returns:
      [B] f32 predictions in *normalized* target space (the caller holds
      the target shift/scale).
    """
    z = (x - shift) / scale
    return mlp_forward(params, z, in_dim)


def loss_fn(params, x, y, shift, scale, in_dim: int):
    pred = infer(params, x, shift, scale, in_dim)
    return jnp.mean((pred - y) ** 2)


def train_step(params, x, y, shift, scale, lr, in_dim: int):
    """One SGD step. Returns (new_params, loss). Lowered by aot.py."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, shift, scale, in_dim)
    return params - lr * grads, loss


def pi_forward(x, exponents, block_b: int = 64):
    """Layer-2 wrapper over the Layer-1 Pallas kernel (quantized signals
    in, Π products out). Lowered per system by aot.py."""
    return pi_products(x, exponents, block_b=block_b)


def pi_then_infer(params, x_q, shift, scale, exponents, frac_bits: int = 15):
    """Fused preprocessing + inference: quantized signals -> Π (Pallas,
    bit-exact with the hardware) -> float features -> Φ prediction.
    This is the full Figure-3 pipeline as one artifact.

    The target-group product Π₀ is *excluded* from the features (it
    contains the quantity being inferred); for N == 1 the feature
    degenerates to the constant 1.
    """
    pis = pi_forward(x_q, exponents)  # [B, N] int32
    scale_q = jnp.float32(1 << frac_bits)
    f = pis.astype(jnp.float32) / scale_q
    n = len(exponents)
    feats = f[:, 1:] if n > 1 else jnp.ones_like(f[:, :1])
    return infer(params, feats, shift, scale, feats.shape[1])
