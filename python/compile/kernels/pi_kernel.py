"""Layer-1 Pallas kernel: batched fixed-point Π-product evaluation.

This is the compute hot-spot of the in-sensor inference engine: given a
batch of quantized sensor signals (Q-format signed fixed point, int32
storage) and a static integer exponent matrix from the Buckingham
Π-search, compute the dimensionless products

    Π_j = prod_i  s_i ** E[j, i]

with *bit-exact* fixed-point semantics matching the generated RTL, the
Rust software model (`rust/src/fixedpoint`), and the gate-level netlist:

* multiply: full-width product, round half up at the fraction point,
  saturate to the word width;
* divide:   sign-magnitude restoring division of (|a| << frac) / |b|
  (truncating), divide-by-zero saturates toward the dividend's sign;
* op order: the canonical monomial schedule — numerator factors in symbol
  order, then denominator factors in symbol order (`monomial_ops` in
  `rust/src/fixedpoint/ops.rs`). Rounding composes identically everywhere.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets a
tiny FPGA, not a GPU — there is no warp/tensor-core structure to port.
The TPU-shaped mapping is: BlockSpec tiles the *batch* dimension into
VMEM-resident blocks (the analogue of the paper's per-sample parallel Π
datapaths is lane-level parallelism across the batch), the Π loop and the
per-Π op chain are fully unrolled at trace time (they are static,
compiler-known structures — exactly like the generated RTL microprogram),
and all arithmetic stays in integer lanes on the VPU; the MXU is not used
because monomial evaluation is elementwise, not a contraction.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom calls, and interpret mode lowers to plain HLO that
the Rust runtime executes directly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Q16.15 by default; kept in sync with rust/src/fixedpoint/qformat.rs.
DEFAULT_INT_BITS = 16
DEFAULT_FRAC_BITS = 15


def qparams(int_bits: int = DEFAULT_INT_BITS, frac_bits: int = DEFAULT_FRAC_BITS):
    """Width-derived constants for a Q(int_bits, frac_bits) format."""
    width = 1 + int_bits + frac_bits
    return {
        "width": width,
        "frac": frac_bits,
        "one": 1 << frac_bits,
        "max_raw": (1 << (width - 1)) - 1,
        "min_raw": -(1 << (width - 1)),
    }


def _fx_mul(a, b, q):
    """Bit-exact fixed-point multiply on int64 lanes."""
    prod = a * b
    rounded = (prod + (1 << (q["frac"] - 1))) >> q["frac"]
    return jnp.clip(rounded, q["min_raw"], q["max_raw"])


def _fx_div(a, b, q):
    """Bit-exact fixed-point divide on int64 lanes (sign-magnitude
    truncating, saturating, dbz saturates by dividend sign)."""
    na = jnp.abs(a) << q["frac"]
    nb = jnp.abs(b)
    safe = jnp.where(nb == 0, jnp.int64(1), nb)
    quot = na // safe
    sign = (a < 0) != (b < 0)
    signed = jnp.where(sign, -quot, quot)
    sat = jnp.clip(signed, q["min_raw"], q["max_raw"])
    dbz = jnp.where(a >= 0, jnp.int64(q["max_raw"]), jnp.int64(q["min_raw"]))
    return jnp.where(b == 0, dbz, sat)


def monomial_ops(exponents: Sequence[int]):
    """Canonical serial op schedule — mirrors `fixedpoint::monomial_ops`.

    Returns a list of ("load"|"load_one"|"mul"|"div", symbol_index).
    """
    ops = []
    loaded = False
    for i, e in enumerate(exponents):
        for _ in range(max(e, 0)):
            if not loaded:
                ops.append(("load", i))
                loaded = True
            else:
                ops.append(("mul", i))
    if not loaded:
        ops.append(("load_one", 0))
    for i, e in enumerate(exponents):
        for _ in range(max(-e, 0)):
            ops.append(("div", i))
    return ops


def _pi_block_kernel(x_ref, o_ref, *, exponents, q):
    """Pallas kernel body: one batch tile, all Π products unrolled."""
    x = x_ref[...].astype(jnp.int64)  # [BB, k]
    outs = []
    for exps in exponents:
        acc = None
        for op, i in monomial_ops(exps):
            if op == "load":
                acc = x[:, i]
            elif op == "load_one":
                acc = jnp.full(x.shape[:1], q["one"], dtype=jnp.int64)
            elif op == "mul":
                acc = _fx_mul(acc, x[:, i], q)
            else:
                acc = _fx_div(acc, x[:, i], q)
        outs.append(acc)
    o_ref[...] = jnp.stack(outs, axis=-1).astype(jnp.int32)


def pi_products(
    x,
    exponents: Sequence[Sequence[int]],
    *,
    int_bits: int = DEFAULT_INT_BITS,
    frac_bits: int = DEFAULT_FRAC_BITS,
    block_b: int = 64,
):
    """Compute Π products for a batch of quantized signals.

    Args:
      x: int32 array [B, k] of Q-format raw values.
      exponents: static N×k integer exponent matrix.
      block_b: batch tile size (VMEM block).

    Returns:
      int32 array [B, N] of Q-format Π values.
    """
    b, k = x.shape
    n = len(exponents)
    exponents = tuple(tuple(int(e) for e in row) for row in exponents)
    for row in exponents:
        assert len(row) == k, "exponent row arity mismatch"
    q = qparams(int_bits, frac_bits)
    bb = min(block_b, b)
    assert b % bb == 0, f"batch {b} not divisible by block {bb}"
    kernel = functools.partial(_pi_block_kernel, exponents=exponents, q=q)
    return pl.pallas_call(
        kernel,
        grid=(b // bb,),
        in_specs=[pl.BlockSpec((bb, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(x)
