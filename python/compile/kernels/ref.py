"""Pure-jnp correctness oracle for the Pallas Π kernel.

Implements the identical fixed-point semantics with plain `jnp` ops and no
Pallas — the reference the kernel is tested against (pytest + hypothesis),
and an independent re-derivation of the semantics defined in
`rust/src/fixedpoint/ops.rs`. A scalar python-int implementation is also
provided as a third, fully independent oracle.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from .pi_kernel import monomial_ops, qparams


def fx_mul_ref(a: int, b: int, int_bits: int = 16, frac_bits: int = 15):
    """Scalar reference multiply (python ints, exact)."""
    q = qparams(int_bits, frac_bits)
    rounded = (a * b + (1 << (frac_bits - 1))) >> frac_bits
    return max(q["min_raw"], min(q["max_raw"], rounded))


def fx_div_ref(a: int, b: int, int_bits: int = 16, frac_bits: int = 15):
    """Scalar reference divide (python ints, exact)."""
    q = qparams(int_bits, frac_bits)
    if b == 0:
        return q["max_raw"] if a >= 0 else q["min_raw"]
    quot = (abs(a) << frac_bits) // abs(b)
    signed = -quot if (a < 0) != (b < 0) else quot
    return max(q["min_raw"], min(q["max_raw"], signed))


def pi_products_scalar(
    values: Sequence[int],
    exponents: Sequence[Sequence[int]],
    int_bits: int = 16,
    frac_bits: int = 15,
):
    """Evaluate all Π monomials for one sample with python-int arithmetic."""
    q = qparams(int_bits, frac_bits)
    outs = []
    for exps in exponents:
        acc = 0
        for op, i in monomial_ops(exps):
            if op == "load":
                acc = values[i]
            elif op == "load_one":
                acc = q["one"]
            elif op == "mul":
                acc = fx_mul_ref(acc, values[i], int_bits, frac_bits)
            else:
                acc = fx_div_ref(acc, values[i], int_bits, frac_bits)
        outs.append(acc)
    return outs


def pi_products_ref(x, exponents, int_bits: int = 16, frac_bits: int = 15):
    """Vectorized jnp reference: same semantics, no Pallas.

    Args:
      x: int32 [B, k].
    Returns:
      int32 [B, N].
    """
    q = qparams(int_bits, frac_bits)
    x64 = x.astype(jnp.int64)
    outs = []
    for exps in exponents:
        acc = None
        for op, i in monomial_ops(exps):
            if op == "load":
                acc = x64[:, i]
            elif op == "load_one":
                acc = jnp.full(x64.shape[:1], q["one"], dtype=jnp.int64)
            elif op == "mul":
                prod = acc * x64[:, i]
                acc = jnp.clip(
                    (prod + (1 << (frac_bits - 1))) >> frac_bits,
                    q["min_raw"],
                    q["max_raw"],
                )
            else:
                b = x64[:, i]
                na = jnp.abs(acc) << frac_bits
                nb = jnp.abs(b)
                safe = jnp.where(nb == 0, jnp.int64(1), nb)
                quot = na // safe
                sign = (acc < 0) != (b < 0)
                signed = jnp.where(sign, -quot, quot)
                sat = jnp.clip(signed, q["min_raw"], q["max_raw"])
                dbz = jnp.where(
                    acc >= 0, jnp.int64(q["max_raw"]), jnp.int64(q["min_raw"])
                )
                acc = jnp.where(b == 0, dbz, sat)
        outs.append(acc)
    return jnp.stack(outs, axis=-1).astype(jnp.int32)
