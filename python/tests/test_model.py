"""Layer-2 model tests: MLP forward/backward, train-step descent, the
fused Π→Φ pipeline, and parameter-layout stability (the Rust trainer
depends on the flat layout)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.pi_kernel import qparams

Q = qparams()


def test_param_count_matches_layout():
    for in_dim in [1, 2, 5]:
        p = model.init_params(jax.random.PRNGKey(0), in_dim)
        assert p.shape == (model.param_count(in_dim),)
        assert p.dtype == jnp.float32


def test_infer_shapes_and_standardization():
    in_dim = 3
    p = model.init_params(jax.random.PRNGKey(1), in_dim)
    x = jnp.ones((8, in_dim), jnp.float32) * 5.0
    shift = jnp.full((in_dim,), 5.0, jnp.float32)
    scale = jnp.ones((in_dim,), jnp.float32)
    out = model.infer(p, x, shift, scale, in_dim)
    assert out.shape == (8,)
    # Standardized input is all-zero -> output equals the bias path and is
    # identical across the batch.
    assert np.allclose(np.asarray(out), np.asarray(out)[0])


def test_train_step_descends_on_linear_problem():
    in_dim = 2
    key = jax.random.PRNGKey(42)
    p = model.init_params(key, in_dim)
    x = jax.random.normal(key, (64, in_dim), jnp.float32)
    y = 2.0 * x[:, 0] - 0.7 * x[:, 1] + 0.3
    shift = jnp.zeros((in_dim,), jnp.float32)
    scale = jnp.ones((in_dim,), jnp.float32)
    losses = []
    for step in range(400):
        lr = jnp.float32(0.1 * (1.0 - 0.9 * step / 400))  # linear decay
        p, loss = model.train_step(p, x, y, shift, scale, lr, in_dim)
        losses.append(float(loss))
    assert losses[-1] < losses[0] / 20, f"{losses[0]} -> {losses[-1]}"
    assert losses[-1] < 0.15


def test_train_step_is_pure_sgd():
    # params' = params - lr * grad: with lr=0 nothing changes.
    in_dim = 1
    p = model.init_params(jax.random.PRNGKey(3), in_dim)
    x = jnp.ones((4, 1), jnp.float32)
    y = jnp.zeros((4,), jnp.float32)
    s = jnp.zeros((1,), jnp.float32)
    sc = jnp.ones((1,), jnp.float32)
    p2, _ = model.train_step(p, x, y, s, sc, jnp.float32(0.0), in_dim)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p2))


def test_pi_then_infer_excludes_target_group():
    # Two-group system: the fused pipeline must feed only Π₁ (not the
    # target group Π₀) to the model.
    exps = ((1, -1, 0), (0, 1, -1))
    in_dim = 1
    p = model.init_params(jax.random.PRNGKey(9), in_dim)
    shift = jnp.zeros((in_dim,), jnp.float32)
    scale = jnp.ones((in_dim,), jnp.float32)
    one = Q["one"]
    # Two inputs differing ONLY in signal 0, which only Π₀ uses.
    xa = jnp.asarray([[2 * one, one, one]], jnp.int32)
    xb = jnp.asarray([[7 * one, one, one]], jnp.int32)
    pa = model.pi_then_infer(p, xa, shift, scale, exps)
    pb = model.pi_then_infer(p, xb, shift, scale, exps)
    assert np.allclose(np.asarray(pa), np.asarray(pb))


def test_pi_then_infer_single_group_uses_constant_feature():
    exps = ((2, -1, 1),)
    in_dim = 1
    p = model.init_params(jax.random.PRNGKey(11), in_dim)
    shift = jnp.zeros((in_dim,), jnp.float32)
    scale = jnp.ones((in_dim,), jnp.float32)
    one = Q["one"]
    xa = jnp.asarray([[one, one, one]], jnp.int32)
    xb = jnp.asarray([[3 * one, 2 * one, one]], jnp.int32)
    pa = model.pi_then_infer(p, xa, shift, scale, exps)
    pb = model.pi_then_infer(p, xb, shift, scale, exps)
    # N=1: features degenerate to the constant 1 → identical predictions.
    assert np.allclose(np.asarray(pa), np.asarray(pb))


def test_mlp_gradient_matches_numeric():
    in_dim = 2
    p = model.init_params(jax.random.PRNGKey(5), in_dim)
    x = jax.random.normal(jax.random.PRNGKey(6), (8, in_dim), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(7), (8,), jnp.float32)
    s = jnp.zeros((in_dim,), jnp.float32)
    sc = jnp.ones((in_dim,), jnp.float32)
    g = jax.grad(model.loss_fn)(p, x, y, s, sc, in_dim)
    # Spot-check 5 coordinates against central differences.
    idxs = [0, 7, 33, 100, int(p.shape[0]) - 1]
    eps = 1e-3
    for i in idxs:
        pp = p.at[i].add(eps)
        pm = p.at[i].add(-eps)
        num = (
            model.loss_fn(pp, x, y, s, sc, in_dim)
            - model.loss_fn(pm, x, y, s, sc, in_dim)
        ) / (2 * eps)
        assert abs(float(g[i]) - float(num)) < 5e-3, f"coord {i}"
