"""AOT pipeline tests: HLO-text emission well-formedness and consistency
with the Π-search interchange (when the Rust export has been generated)."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.pi_kernel import pi_products

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_parseable_module():
    exps = ((1, -1),)

    def fn(x):
        return (pi_products(x, exps),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 2), jnp.int32))
    text = aot.to_hlo_text(lowered)
    # HLO text structure: module header + ENTRY computation.
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # Tuple return (the Rust loader unconditionally decomposes tuples).
    assert "tuple(" in text or "(s32[4,2]" in text


def test_hlo_has_no_custom_calls():
    # interpret=True must lower Pallas to plain HLO: a Mosaic custom-call
    # would be unexecutable on the CPU PJRT client.
    exps = ((2, -1, 1), (0, 1, -1))

    def fn(x):
        return (pi_products(x, exps),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((64, 3), jnp.int32))
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text, "Mosaic custom call leaked into HLO"


def test_train_step_lowers_with_tuple_output():
    in_dim = 2
    p = model.param_count(in_dim)

    def fn(params, x, y, shift, scale, lr):
        return model.train_step(params, x, y, shift, scale, lr, in_dim)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((64, in_dim), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.float32),
        jax.ShapeDtypeStruct((in_dim,), jnp.float32),
        jax.ShapeDtypeStruct((in_dim,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert f"f32[{p}]" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "pisearch.json")),
    reason="run `make artifacts` first",
)
def test_pisearch_interchange_shape():
    with open(os.path.join(ART, "pisearch.json")) as f:
        desc = json.load(f)
    assert desc["format"] == {"int_bits": 16, "frac_bits": 15}
    systems = {s["id"]: s for s in desc["systems"]}
    assert len(systems) == 7
    pend = systems["pendulum"]
    assert len(pend["ports"]) == 3
    assert len(pend["exponents"]) == 1
    assert pend["latency"] == 115
    for s in desc["systems"]:
        k = len(s["ports"])
        for row in s["exponents"]:
            assert len(row) == k
        # Target group isolates the target: exactly one group references
        # the target port.
        tp = s["ports"].index(s["target_index"])
        holders = [g for g in s["exponents"] if g[tp] != 0]
        assert len(holders) == 1


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.txt")),
    reason="run `make artifacts` first",
)
def test_manifest_complete():
    with open(os.path.join(ART, "manifest.txt")) as f:
        names = set(f.read().split())
    with open(os.path.join(ART, "pisearch.json")) as f:
        systems = [s["id"] for s in json.load(f)["systems"]]
    for sid in systems:
        for art in [
            f"pi_{sid}_b1",
            f"pi_{sid}_b64",
            f"phi_infer_{sid}_b1",
            f"phi_infer_{sid}_b64",
            f"phi_train_{sid}",
            f"raw_infer_{sid}_b64",
            f"raw_train_{sid}",
            f"pipeline_{sid}_b64",
        ]:
            assert art in names, f"missing {art}"
            assert os.path.exists(os.path.join(ART, f"{art}.hlo.txt"))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "pisearch.json")),
    reason="run `make artifacts` first",
)
def test_kernel_agrees_with_exported_exponents_on_traces():
    """The kernel over the exported exponent matrices produces Π values
    close to f64 for in-range monomials (mirrors the Rust-side test)."""
    with open(os.path.join(ART, "pisearch.json")) as f:
        desc = json.load(f)
    one = 1 << 15
    rng = np.random.default_rng(11)
    for s in desc["systems"]:
        exps = tuple(tuple(r) for r in s["exponents"])
        k = len(s["ports"])
        vals = rng.uniform(0.5, 4.0, size=(8, k))
        x = jnp.asarray(np.round(vals * one).astype(np.int32))
        out = np.asarray(pi_products(x, exps)).astype(np.float64) / one
        for j in range(8):
            for gi, row in enumerate(exps):
                truth = float(np.prod(vals[j] ** np.asarray(row)))
                assert abs(out[j, gi] - truth) < 0.02 * max(abs(truth), 1.0), (
                    s["id"],
                    j,
                    gi,
                )
