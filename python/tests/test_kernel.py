"""Pallas Π kernel vs the pure-jnp and python-int oracles — the core
Layer-1 correctness signal, swept with hypothesis over shapes, formats and
exponent matrices."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pi_kernel import monomial_ops, pi_products, qparams
from compile.kernels.ref import (
    fx_div_ref,
    fx_mul_ref,
    pi_products_ref,
    pi_products_scalar,
)

Q = qparams()


def quantize(v: float) -> int:
    scaled = v * Q["one"]
    r = np.floor(scaled + 0.5) if scaled >= 0 else np.ceil(scaled - 0.5)
    return int(max(Q["min_raw"], min(Q["max_raw"], r)))


# ---------- scalar semantics ----------------------------------------------------


def test_mul_identity():
    one = Q["one"]
    for v in [0, 1, -5, 12345, Q["max_raw"], Q["min_raw"] + 1]:
        assert fx_mul_ref(v, one) == v


def test_mul_rounds_half_up():
    assert fx_mul_ref(16384, 1) == 1  # 0.5 * lsb rounds up
    assert fx_mul_ref(16383, 1) == 0


def test_mul_saturates():
    big = quantize(30000.0)
    assert fx_mul_ref(big, big) == Q["max_raw"]
    assert fx_mul_ref(big, -big) == Q["min_raw"]


def test_div_identity_and_truncation():
    one = Q["one"]
    for v in [0, 7, -7, 99999]:
        assert fx_div_ref(v, one) == v
    assert fx_div_ref(quantize(1.0), quantize(3.0)) == 10922
    assert fx_div_ref(quantize(-1.0), quantize(3.0)) == -10922


def test_div_by_zero_saturates():
    assert fx_div_ref(5, 0) == Q["max_raw"]
    assert fx_div_ref(-5, 0) == Q["min_raw"]
    assert fx_div_ref(0, 0) == Q["max_raw"]


def test_monomial_ops_schedule():
    assert monomial_ops([2, -1, 0, 1]) == [
        ("load", 0),
        ("mul", 0),
        ("mul", 3),
        ("div", 1),
    ]
    assert monomial_ops([-1, -1]) == [("load_one", 0), ("div", 0), ("div", 1)]


# ---------- kernel vs oracles ----------------------------------------------------

PENDULUM_EXPS = ((2, -1, 1),)  # period², /length, ×g over ports
FLIGHT_EXPS = ((-1, 1, 1), (1, -1, 1))  # two groups, 3 ports (example)


def run_all(x, exps):
    """Kernel, jnp oracle and scalar oracle on the same input."""
    kern = np.asarray(pi_products(x, exps))
    ref = np.asarray(pi_products_ref(x, exps))
    scal = np.stack(
        [
            np.asarray(pi_products_scalar([int(v) for v in row], exps))
            for row in np.asarray(x)
        ]
    )
    return kern, ref, scal


def test_kernel_matches_oracles_pendulum():
    rng = np.random.default_rng(42)
    x = rng.integers(-(1 << 18), 1 << 18, size=(64, 3), dtype=np.int32)
    kern, ref, scal = run_all(jnp.asarray(x), PENDULUM_EXPS)
    np.testing.assert_array_equal(kern, ref)
    np.testing.assert_array_equal(kern, scal)


def test_kernel_known_value():
    # g t²/l with t=2, l=1.5, g=9.81: Π ≈ 26.16.
    x = jnp.asarray(
        [[quantize(2.0), quantize(1.5), quantize(9.81)]], dtype=jnp.int32
    )
    out = np.asarray(pi_products(x, PENDULUM_EXPS))[0, 0]
    assert abs(out / Q["one"] - 9.81 * 4 / 1.5) < 0.01


def test_kernel_multi_group():
    rng = np.random.default_rng(7)
    x = rng.integers(1, 1 << 19, size=(16, 3), dtype=np.int32)
    kern, ref, scal = run_all(jnp.asarray(x), FLIGHT_EXPS)
    assert kern.shape == (16, 2)
    np.testing.assert_array_equal(kern, ref)
    np.testing.assert_array_equal(kern, scal)


def test_kernel_batch_blocking():
    # B=128 with block 64: two grid steps must agree with one-shot ref.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(1, 1 << 19, size=(128, 3), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(pi_products(x, PENDULUM_EXPS, block_b=64)),
        np.asarray(pi_products_ref(x, PENDULUM_EXPS)),
    )


def test_division_by_zero_in_kernel():
    x = jnp.asarray([[quantize(2.0), 0, quantize(9.81)]], dtype=jnp.int32)
    out = np.asarray(pi_products(x, PENDULUM_EXPS))[0, 0]
    assert out == Q["max_raw"]


# ---------- hypothesis sweeps -----------------------------------------------------


@st.composite
def exponent_matrix(draw):
    k = draw(st.integers(min_value=1, max_value=5))
    n = draw(st.integers(min_value=1, max_value=3))
    rows = draw(
        st.lists(
            st.lists(st.integers(min_value=-3, max_value=3), min_size=k, max_size=k),
            min_size=n,
            max_size=n,
        )
    )
    return tuple(tuple(r) for r in rows)


@settings(max_examples=25, deadline=None)
@given(
    exps=exponent_matrix(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    batch=st.sampled_from([1, 2, 8]),
)
def test_kernel_matches_scalar_oracle_random(exps, seed, batch):
    k = len(exps[0])
    rng = np.random.default_rng(seed)
    # Mix of magnitudes incl. negatives, zeros and extremes.
    x = rng.integers(-(1 << 22), 1 << 22, size=(batch, k), dtype=np.int32)
    x[rng.random(x.shape) < 0.05] = 0
    kern = np.asarray(pi_products(jnp.asarray(x), exps))
    scal = np.stack(
        [np.asarray(pi_products_scalar([int(v) for v in row], exps)) for row in x]
    )
    np.testing.assert_array_equal(kern, scal)


@settings(max_examples=50, deadline=None)
@given(
    a=st.integers(min_value=Q["min_raw"], max_value=Q["max_raw"]),
    b=st.integers(min_value=Q["min_raw"], max_value=Q["max_raw"]),
)
def test_scalar_mul_within_ulp_of_float(a, b):
    """Fixed-point multiply approximates real multiplication to 1 ulp
    (when the true product is representable)."""
    true = (a / Q["one"]) * (b / Q["one"])
    got = fx_mul_ref(a, b) / Q["one"]
    if Q["min_raw"] / Q["one"] < true < Q["max_raw"] / Q["one"]:
        assert abs(got - true) <= 1.0 / Q["one"] + 1e-12
    else:
        assert got in (Q["max_raw"] / Q["one"], Q["min_raw"] / Q["one"])


@settings(max_examples=30, deadline=None)
@given(
    a=st.integers(min_value=-(1 << 25), max_value=1 << 25),
    b=st.integers(min_value=1, max_value=1 << 25),
)
def test_scalar_div_mul_roundtrip_bound(a, b):
    """(a / b) * b stays within b ulps of a (truncation error bound)."""
    q_ = fx_div_ref(a, b)
    if q_ in (Q["max_raw"], Q["min_raw"]):
        return
    back = fx_mul_ref(q_, b)
    assert abs(back - a) <= b / Q["one"] + 2


@settings(max_examples=10, deadline=None)
@given(frac=st.sampled_from([7, 11, 15, 23]))
def test_parametric_fraction_widths(frac):
    """The kernel honours parametric Q formats (paper: 'fully parametric
    with respect to the length of the fixed point representation')."""
    int_bits = 30 - frac
    one = 1 << frac
    x = jnp.asarray([[2 * one, 3 * one]], dtype=jnp.int32)
    out = np.asarray(
        pi_products(x, ((1, 1),), int_bits=int_bits, frac_bits=frac)
    )[0, 0]
    assert out == 6 * one
