//! Multi-system in-sensor serving (DESIGN.md §4, F4): one coordinator
//! per physical system, all three Π paths exercised, including
//! hardware-in-the-loop mode where every served sample runs through the
//! cycle-accurate simulation of the generated RTL.
//!
//! ```text
//! make artifacts && cargo run --release --example insensor_server [-- <samples>]
//! ```

use dimsynth::coordinator::{InferenceServer, PiPath, SensorInput, ServerConfig};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::stim::{self, Lfsr32};
use dimsynth::train::{self, FeatureKind};
use std::time::Duration;

fn serve_one(system: &str, n: usize, pi_path: PiPath) -> anyhow::Result<(f64, f64)> {
    let trained = train::run_training("artifacts", system, FeatureKind::Pi, 500, 0xBEEF)?;
    let export = trained.dataset.export.clone();
    let server = InferenceServer::start(
        ServerConfig {
            artifacts: "artifacts".into(),
            system: system.into(),
            max_batch: 64,
            linger: Duration::from_micros(200),
            pi_path,
        },
        trained,
    )?;
    let mut rng = Lfsr32::new(0x51_5E11);
    let mut pending = Vec::with_capacity(n);
    let mut truths = Vec::with_capacity(n);
    for _ in 0..n {
        let s = stim::sample(system, &mut rng).unwrap();
        truths.push(s[export.target_index]);
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut rel = 0f64;
    let mut cnt = 0usize;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let p = rx.recv().expect("response")?;
        if p.target_estimate.is_finite() && truth.abs() > 1e-12 {
            rel += ((p.target_estimate - truth) / truth).abs();
            cnt += 1;
        }
    }
    let stats = server.shutdown();
    Ok((stats.throughput(), 100.0 * rel / cnt.max(1) as f64))
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    println!(
        "{:<24} {:>14} {:>14} {:>16}",
        "system", "path", "samples/s", "mean |rel err| %"
    );
    for system in ["pendulum", "beam", "unpowered_flight", "vibrating_string", "spring_mass"] {
        for (path, label, count) in [
            (PiPath::Native, "native", n),
            (PiPath::Hlo, "pallas/pjrt", n),
            // The RTL-sim path simulates every clock cycle of the
            // generated hardware — far slower, so a smaller stream.
            (PiPath::RtlSim, "rtl-sim", n.min(256)),
        ] {
            let (thr, err) = serve_one(system, count, path)?;
            println!("{system:<24} {label:>14} {thr:>14.0} {err:>16.3}");
        }
    }
    Ok(())
}
