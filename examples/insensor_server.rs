//! Multi-system in-sensor serving (DESIGN.md §4, F4): every coordinator
//! endpoint serves from **one warm `ServeSet`** (shared compiled
//! artifact graph — no per-endpoint cold compile), all three Π paths
//! exercised, including hardware-in-the-loop mode where every served
//! sample runs through the cycle-accurate simulation of the generated
//! RTL. A mixed-system power-request flood exercises the cross-system
//! batcher at the end.
//!
//! ```text
//! make artifacts && cargo run --release --example insensor_server [-- <samples>]
//! ```

use dimsynth::coordinator::{
    InferenceServer, PiPath, PowerRequest, SensorInput, ServeSet, ServerConfig,
};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::FlowConfig;
use dimsynth::stim::{self, Lfsr32};
use dimsynth::train::{self, FeatureKind};
use std::time::{Duration, Instant};

const SYSTEMS: [&str; 5] =
    ["pendulum", "beam", "unpowered_flight", "vibrating_string", "spring_mass"];

fn serve_one(
    set: &ServeSet,
    system: &str,
    n: usize,
    pi_path: PiPath,
) -> anyhow::Result<(f64, f64)> {
    let trained = train::run_training("artifacts", system, FeatureKind::Pi, 500, 0xBEEF)?;
    let export = trained.dataset.export.clone();
    let server = InferenceServer::start_shared(
        ServerConfig {
            artifacts: "artifacts".into(),
            system: system.into(),
            max_batch: 64,
            linger: Duration::from_micros(200),
            pi_path,
        },
        trained,
        set.handle(system).expect("system is in the serve set"),
    )?;
    let mut rng = Lfsr32::new(0x51_5E11);
    let mut pending = Vec::with_capacity(n);
    let mut truths = Vec::with_capacity(n);
    for _ in 0..n {
        let s = stim::sample(system, &mut rng).unwrap();
        truths.push(s[export.target_index]);
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut rel = 0f64;
    let mut cnt = 0usize;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let p = rx.recv().expect("response")?;
        if p.target_estimate.is_finite() && truth.abs() > 1e-12 {
            rel += ((p.target_estimate - truth) / truth).abs();
            cnt += 1;
        }
    }
    let stats = server.shutdown();
    Ok((stats.throughput(), 100.0 * rel / cnt.max(1) as f64))
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1024);

    // One shared compilation boot for every endpoint below.
    let t = Instant::now();
    let set = ServeSet::boot(&SYSTEMS, FlowConfig::default(), None)?;
    println!(
        "booted {} systems on one warm FlowSet in {:.0} ms\n",
        set.len(),
        t.elapsed().as_secs_f64() * 1e3
    );

    println!(
        "{:<24} {:>14} {:>14} {:>16}",
        "system", "path", "samples/s", "mean |rel err| %"
    );
    for system in SYSTEMS {
        for (path, label, count) in [
            (PiPath::Native, "native", n),
            (PiPath::Hlo, "pallas/pjrt", n),
            // The RTL-sim path simulates every clock cycle of the
            // generated hardware — far slower, so a smaller stream.
            (PiPath::RtlSim, "rtl-sim", n.min(256)),
        ] {
            let (thr, err) = serve_one(&set, system, count, path)?;
            println!("{system:<24} {label:>14} {thr:>14.0} {err:>16.3}");
        }
    }

    // Mixed-system power-request flood through the global batcher.
    let flood = 512usize;
    let batcher = set.power_batcher(Duration::ZERO, 2);
    let t = Instant::now();
    let pending: Vec<_> = (0..flood)
        .map(|i| {
            batcher.submit(
                i % set.len(),
                PowerRequest { seed: 0xF10_0D ^ i as u32, f_hz: 6.0e6 },
            )
        })
        .collect();
    for rx in pending {
        rx.recv().expect("estimate")?;
    }
    let dt = t.elapsed();
    let stats = batcher.shutdown();
    println!(
        "\npower flood: {} mixed-system requests in {:.0} ms ({:.0} req/s, {} batches, {} cross-system)",
        stats.requests,
        dt.as_secs_f64() * 1e3,
        stats.requests as f64 / dt.as_secs_f64(),
        stats.batches,
        stats.mixed_batches
    );
    Ok(())
}
