//! Quickstart: the complete dimensional-circuit-synthesis flow on the
//! paper's running example (Fig. 2 — a sensor-instrumented unpowered
//! glider), using only the public library API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Walks all four steps of Fig. 4: ① the Newton description, ② the
//! compiler (Π-search + RTL generation + synthesis/timing/power reports),
//! ③ a glimpse of offline calibration data, ④ executing the generated
//! design in the cycle-accurate simulator on a quantized observation.

use dimsynth::fixedpoint::{self, Q16_15};
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::newton;
use dimsynth::rtl;
use dimsynth::stim::{self, Lfsr32};

fn main() -> anyhow::Result<()> {
    // ── Step 1: the physical-system description ────────────────────────
    let entry = newton::by_id("unpowered_flight").expect("corpus entry");
    println!("── Newton specification ({}) ──", entry.display_name);
    println!("{}", entry.source.trim());

    // One compilation session drives every stage below; each stage
    // computes on first demand and is memoized.
    let mut flow = Flow::for_entry(entry.clone(), FlowConfig::default());

    let model = flow.parsed()?.clone();
    println!("\nresolved {} symbols:", model.k());
    for s in &model.symbols {
        println!("  {:<10} : {:<12} [{}]", s.name, s.dimension.si_unit(), s.dimension);
    }

    // ── Step 2: dimensional circuit synthesis ───────────────────────────
    println!("\n── Buckingham Π analysis ──\n{}", flow.pis()?);
    println!("generated RTL: {} lines of Verilog", flow.verilog()?.lines().count());

    let (lut4_cells, gate_count, dffs) = {
        let mapped = flow.netlist()?;
        (mapped.lut4_cells, mapped.gate_count, mapped.dffs)
    };
    let t = flow.timing()?;
    let p = flow.power()?;
    println!("\n── implementation report (iCE40 model) ──");
    println!("  LUT4 cells : {lut4_cells}");
    println!("  gate count : {gate_count}");
    println!("  flip-flops : {dffs}");
    println!("  Fmax       : {:.2} MHz", t.fmax_mhz);
    println!("  latency    : {} cycles", flow.latency()?);
    println!("  power      : {:.1} mW @6MHz, {:.1} mW @12MHz", p.mw_6mhz, p.mw_12mhz);
    let design = flow.rtl()?.clone();

    // ── Step 3: what the calibration step would see ─────────────────────
    let mut rng = Lfsr32::new(0xC0FFEE);
    let sample = stim::sample("unpowered_flight", &mut rng).expect("trace");
    println!("\n── one synthetic observation ──");
    for (s, v) in model.symbols.iter().zip(&sample) {
        println!("  {:<10} = {:>10.4} {}", s.name, v, s.dimension.si_unit());
    }

    // ── Step 4: run the synthesized hardware on it ──────────────────────
    let inputs = design.select_inputs(
        &sample.iter().map(|&v| Q16_15.from_f64(v)).collect::<Vec<_>>(),
    );
    let result = rtl::run_once(&design, &inputs);
    println!("\n── cycle-accurate execution ──");
    println!("  finished in {} cycles", result.cycles);
    for (u, (unit, &pi)) in design.units.iter().zip(&result.outputs).enumerate() {
        println!("  Π{} = {:<10.5} ({})", u + 1, Q16_15.to_f64(pi), unit.expr);
    }
    // Sanity: the software model agrees bit for bit.
    assert_eq!(result.outputs, rtl::sim::reference_outputs(&design, &inputs));
    let _ = fixedpoint::Q16_15;
    println!("\nsoftware model matches the hardware bit-for-bit ✓");
    Ok(())
}
