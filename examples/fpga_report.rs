//! Regenerate the paper's Table 1 (the full experimental evaluation) and
//! print it side by side with the published values.
//!
//! ```text
//! cargo run --release --example fpga_report [-- <power-samples>]
//! ```
//!
//! For every corpus system this runs the complete flow: Newton frontend →
//! Π-search → RTL generation → gate-level lowering → LUT4 mapping →
//! STA → LFSR-driven gate-level power simulation.

use dimsynth::fixedpoint::Q16_15;
use dimsynth::report::{self, table1};

fn main() -> anyhow::Result<()> {
    let samples: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    eprintln!("running the full synthesis flow on 7 systems (power window: {samples} activations)…");
    let rows = report::generate_table(Q16_15, samples)?;
    println!("{}", report::render_markdown(&rows));

    // Shape checks the paper's prose makes (§3.A) — fail loudly if the
    // reproduction drifts.
    for r in &rows {
        assert!(r.latency_cycles < 300, "{}: latency claim violated", r.id);
        assert!(r.power_12mhz_mw < 6.5, "{}: power claim violated", r.id);
        let rate = r.fmax_mhz.min(12.0) * 1.0e6 / r.latency_cycles as f64;
        assert!(rate > 10_000.0, "{}: sample-rate claim violated", r.id);
    }
    let pendulum = rows.iter().find(|r| r.id == "pendulum").unwrap();
    let flight = rows.iter().find(|r| r.id == "unpowered_flight").unwrap();
    assert!(
        flight.latency_cycles < pendulum.latency_cycles,
        "parallelism observation violated"
    );
    println!("paper §3.A shape checks: all hold ✓");

    // Per-experiment index entry (DESIGN.md §4, T1).
    let _ = table1::paper_row("pendulum");
    Ok(())
}
