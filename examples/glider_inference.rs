//! End-to-end driver (DESIGN.md §4, F3/F4): the full three-layer system
//! on a real small workload.
//!
//! ```text
//! make artifacts && cargo run --release --example glider_inference
//! ```
//!
//! 1. Compiles the glider (unpowered flight) Newton spec to hardware and
//!    validates all three Π implementations against each other bit for
//!    bit: native fixed point ↔ cycle-accurate RTL sim ↔ the AOT-compiled
//!    Pallas kernel executed through PJRT.
//! 2. Trains the Φ calibration model from Rust through the AOT train-step
//!    executable, logging the loss curve (paper Fig. 4, Step 3).
//! 3. Serves a stream of synthetic in-flight observations through the
//!    threaded coordinator with dynamic batching, reporting latency,
//!    throughput, and online target-recovery accuracy (Step 4).

use dimsynth::coordinator::{InferenceServer, PiPath, SensorInput, ServerConfig};
use dimsynth::fixedpoint::Q16_15;
use dimsynth::flow::{Flow, FlowConfig};
use dimsynth::report::export::export_from_flow;
use dimsynth::rtl;
use dimsynth::runtime::engine;
use dimsynth::runtime::Engine;
use dimsynth::stim::{self, Lfsr32};
use dimsynth::train::{self, FeatureKind};
use std::time::Duration;

const SYSTEM: &str = "unpowered_flight";
const ARTIFACTS: &str = "artifacts";

fn main() -> anyhow::Result<()> {
    // ── 1. three bit-identical Π paths ─────────────────────────────────
    let mut flow = Flow::for_system(SYSTEM, FlowConfig::default())?;
    let export = export_from_flow(&mut flow)?;
    let design = flow.rtl()?.clone();

    let mut eng = Engine::new(ARTIFACTS)?;
    println!("PJRT platform: {}", eng.platform());
    let pi_exe = eng.load(&format!("pi_{SYSTEM}_b64"))?;

    let mut rng = Lfsr32::new(0x6A1DE);
    let kp = export.ports.len();
    let n = export.exponents.len();
    let mut flat = vec![0i64; 64 * kp];
    let mut samples_q: Vec<Vec<i64>> = Vec::new();
    for j in 0..64 {
        let s = stim::sample(SYSTEM, &mut rng).unwrap();
        let q: Vec<i64> = export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        flat[j * kp..(j + 1) * kp].copy_from_slice(&q);
        samples_q.push(q);
    }
    let outs = pi_exe.run(&[engine::i32_matrix(64, kp, &flat)?])?;
    let hlo_pis = engine::to_i32s(&outs[0])?;

    let mut rtl_cycles = 0u64;
    for (j, q) in samples_q.iter().enumerate() {
        let native: Vec<i64> = export
            .exponents
            .iter()
            .map(|e| dimsynth::fixedpoint::eval_monomial(Q16_15, q, e))
            .collect();
        let sim = rtl::run_once(&design, q);
        rtl_cycles += sim.cycles;
        let hlo: Vec<i64> =
            hlo_pis[j * n..(j + 1) * n].iter().map(|&v| v as i64).collect();
        assert_eq!(native, sim.outputs, "RTL sim diverged at sample {j}");
        assert_eq!(native, hlo, "Pallas/PJRT diverged at sample {j}");
    }
    println!(
        "Π cross-validation: 64 samples × {n} products bit-exact across native / RTL-sim / PJRT ✓"
    );
    println!("hardware cost: {} cycles/sample", rtl_cycles / 64);

    // ── 2. offline Φ calibration through the AOT train step ────────────
    let trained = train::run_training(ARTIFACTS, SYSTEM, FeatureKind::Pi, 800, 0x600D)?;
    println!("\nloss curve (every 100 steps):");
    for (i, l) in trained.loss_curve.iter().enumerate() {
        if i % 100 == 0 || i + 1 == trained.loss_curve.len() {
            println!("  step {:>4}: {:.6}", i + 1, l);
        }
    }
    println!("validation RMSE: {:.5} (raw Π₀ units)", trained.val_rmse);

    // ── 3. serve a stream through the coordinator ──────────────────────
    let server = InferenceServer::start(
        ServerConfig {
            artifacts: ARTIFACTS.into(),
            system: SYSTEM.into(),
            max_batch: 64,
            linger: Duration::from_micros(300),
            pi_path: PiPath::Native,
        },
        trained,
    )?;

    let n_stream = 4096;
    let mut pending = Vec::with_capacity(n_stream);
    let mut truths = Vec::with_capacity(n_stream);
    for _ in 0..n_stream {
        let s = stim::sample_noisy(SYSTEM, &mut rng, 0.0).unwrap();
        truths.push(s[export.target_index]);
        let values_q: Vec<i64> =
            export.ports.iter().map(|&si| Q16_15.from_f64(s[si])).collect();
        pending.push(server.submit(SensorInput { values_q }));
    }
    let mut rel = 0f64;
    let mut cnt = 0usize;
    for (rx, truth) in pending.into_iter().zip(truths) {
        let p = rx.recv().expect("response")?;
        if p.target_estimate.is_finite() {
            rel += ((p.target_estimate - truth) / truth).abs();
            cnt += 1;
        }
    }
    let stats = server.shutdown();
    println!("\n── serving report ──\n{stats}");
    println!(
        "online height recovery: mean |relative error| = {:.3}% over {cnt} samples",
        100.0 * rel / cnt as f64
    );

    // Real-time claim: the in-sensor hardware at 12 MHz sustains >10k
    // samples/s; the coordinator must not be the bottleneck.
    assert!(stats.throughput() > 10_000.0, "coordinator slower than the sensor hardware");
    println!("coordinator sustains the paper's >10k samples/s real-time envelope ✓");
    Ok(())
}
